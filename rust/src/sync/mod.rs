//! Peer replication: anti-entropy sync of the semantic cache between
//! bridge nodes (ROADMAP Open item 3, stage one — a two-node fleet).
//!
//! A cache hit earned on one bridge should be a hit everywhere. Each
//! entry carries a [`Stamp`] (`origin` node id + `version` under that
//! node's Lamport write clock); peers periodically exchange per-origin
//! high-water marks and ship only the entries the other side has not
//! seen, resolving conflicts with the deterministic symmetric tiebreaker
//! ([`Stamp::beats`]). Applied remote entries are journaled through the
//! receiver's own WAL, so replication survives restarts and compactions
//! **without coordination**: each node compacts independently and a sync
//! round never needs a peer's WAL history — only its present state.
//!
//! ## Wire format
//!
//! Frames reuse the WAL's record idiom (`persist/wal.rs`): length
//! prefix, FNV-1a content checksum, little-endian throughout.
//!
//! ```text
//! per frame:  [payload_len: u32 LE]
//!             [crc:         u64 LE]        FNV-1a over the payload
//!             [payload:     payload_len bytes]
//!
//! payload:    [msg tag: u8] then per message:
//!   1 HELLO    [proto: u32] [origin: str]
//!   2 SUMMARY  [n: u32] n x ([origin: str] [version: u64])
//!   3 ENTRY    [entry tag: u8] ...
//!   4 DONE     [shipped: u32]
//!
//! entry:
//!   1 EXACT    [key: str] [response: str] [stamp]
//!   2 TOMB     [key: str] [stamp]
//!   3 OBJECT   [text: str] [origin_field: str] [is_document: u8]
//!              [nkeys: u32] nkeys x ([ctype: u8] [vector: f32s])
//!              [stamp]
//!
//! str   = [len: u32] [utf-8 bytes]          f32s = [n: u32] [n x f32 LE]
//! stamp = [origin: str] [version: u64]
//! ```
//!
//! Object vectors travel in **stored form** (pre-normalized rows read
//! straight out of the sender's index), so the receiver inserts them
//! verbatim — replicas are bit-identical and never re-embed.
//!
//! ## Session
//!
//! One round is one TCP connection, strictly turn-taking (no concurrent
//! reads/writes, so plain blocking sockets suffice):
//!
//! 1. dialer → `HELLO`, acceptor → `HELLO` (protocol + distinct node ids)
//! 2. dialer → `SUMMARY`, acceptor → `SUMMARY` (per-origin high-water marks)
//! 3. acceptor streams `ENTRY`* + `DONE` (its delta vs the dialer's marks);
//!    the dialer applies as it reads
//! 4. dialer streams `ENTRY`* + `DONE`; the acceptor applies
//!
//! One bidirectional round therefore converges both nodes on everything
//! either had at step 2. A round that dies mid-stream is safe: every
//! applied entry was journaled before the next read, and the next round's
//! high-water marks simply re-ship the tail.
//!
//! ## Scope and guarantees
//!
//! * **Opt-in and zero-cost when off** — no `--peer`/`--sync-port` means
//!   this module's threads never start and the cache hot path carries no
//!   replication state.
//! * **Trusted network assumed** — the sync listener speaks an
//!   unauthenticated binary protocol and binds a dedicated port; deploy
//!   it on a private interface (unlike the loopback-only admin surface,
//!   peers are usually not on the same host).
//! * `clear` is **local** — a cleared node advertises empty high-water
//!   marks and is re-seeded by its peer on the next round.
//! * Quotas and exchange history are node-local by design; only the
//!   semantic cache (objects, exact entries, tombstones) replicates.
//!
//! [`Stamp`]: crate::cache::Stamp
//! [`Stamp::beats`]: crate::cache::Stamp::beats

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::cache::{CachedType, Stamp, SyncApplied, SyncEntry};
use crate::coordinator::Bridge;
use crate::persist::wal::{put_stamp, put_str, put_u32, put_u64, Cursor};
use crate::util::fnv1a;
use crate::util::json::Json;

/// Protocol version in `HELLO`; bumped on any wire-format change.
pub const PROTO_VERSION: u32 = 1;
/// Frame header: `payload_len: u32` + `crc: u64`.
const FRAME_HEADER: usize = 4 + 8;
/// Sanity cap on one frame's payload, matching the WAL's record cap.
const MAX_FRAME: usize = 64 * 1024 * 1024;
/// Per-socket read/write timeout: a wedged peer fails the round instead
/// of hanging the sync thread forever.
const IO_TIMEOUT: Duration = Duration::from_secs(20);

const MSG_HELLO: u8 = 1;
const MSG_SUMMARY: u8 = 2;
const MSG_ENTRY: u8 = 3;
const MSG_DONE: u8 = 4;

const ENTRY_EXACT: u8 = 1;
const ENTRY_TOMB: u8 = 2;
const ENTRY_OBJECT: u8 = 3;

// ------------------------------------------------------------- framing

fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    if payload.len() > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("sync frame of {} bytes exceeds the cap", payload.len()),
        ));
    }
    let mut rec = Vec::with_capacity(FRAME_HEADER + payload.len());
    rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    rec.extend_from_slice(&fnv1a(payload).to_le_bytes());
    rec.extend_from_slice(payload);
    stream.write_all(&rec)
}

fn read_frame(stream: &mut TcpStream) -> std::io::Result<Vec<u8>> {
    let mut head = [0u8; FRAME_HEADER];
    stream.read_exact(&mut head)?;
    let len = u32::from_le_bytes(head[0..4].try_into().unwrap()) as usize;
    let crc = u64::from_le_bytes(head[4..12].try_into().unwrap());
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "sync frame declares an insane length",
        ));
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    if fnv1a(&payload) != crc {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "sync frame checksum mismatch",
        ));
    }
    Ok(payload)
}

// ------------------------------------------------------------ messages

#[derive(Clone, Debug, PartialEq)]
enum Msg {
    Hello { proto: u32, origin: String },
    Summary { hwms: Vec<(String, u64)> },
    Entry(SyncEntry),
    Done { shipped: u32 },
}

fn encode_entry(out: &mut Vec<u8>, entry: &SyncEntry) {
    match entry {
        SyncEntry::Exact {
            key,
            response,
            stamp,
        } => {
            out.push(ENTRY_EXACT);
            put_str(out, key);
            put_str(out, response);
            put_stamp(out, stamp);
        }
        SyncEntry::Tomb { key, stamp } => {
            out.push(ENTRY_TOMB);
            put_str(out, key);
            put_stamp(out, stamp);
        }
        SyncEntry::Object {
            text,
            origin,
            is_document,
            stamp,
            keys,
        } => {
            out.push(ENTRY_OBJECT);
            put_str(out, text);
            put_str(out, origin);
            out.push(*is_document as u8);
            put_u32(out, keys.len() as u32);
            for (ctype, vector) in keys {
                out.push(ctype.tag());
                crate::persist::wal::put_f32s(out, vector);
            }
            put_stamp(out, stamp);
        }
    }
}

fn decode_entry(c: &mut Cursor<'_>) -> Result<SyncEntry, String> {
    Ok(match c.u8()? {
        ENTRY_EXACT => SyncEntry::Exact {
            key: c.str()?,
            response: c.str()?,
            stamp: c.stamp()?,
        },
        ENTRY_TOMB => SyncEntry::Tomb {
            key: c.str()?,
            stamp: c.stamp()?,
        },
        ENTRY_OBJECT => {
            let text = c.str()?;
            let origin = c.str()?;
            let is_document = c.u8()? != 0;
            let nkeys = c.u32()? as usize;
            let mut keys = Vec::with_capacity(nkeys.min(1024));
            for _ in 0..nkeys {
                let ctype = CachedType::from_tag(c.u8()?)
                    .ok_or_else(|| "bad cached-type tag".to_string())?;
                keys.push((ctype, c.f32s()?));
            }
            SyncEntry::Object {
                text,
                origin,
                is_document,
                stamp: c.stamp()?,
                keys,
            }
        }
        t => return Err(format!("unknown sync entry tag {t}")),
    })
}

impl Msg {
    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Msg::Hello { proto, origin } => {
                out.push(MSG_HELLO);
                put_u32(&mut out, *proto);
                put_str(&mut out, origin);
            }
            Msg::Summary { hwms } => {
                out.push(MSG_SUMMARY);
                put_u32(&mut out, hwms.len() as u32);
                for (origin, version) in hwms {
                    put_str(&mut out, origin);
                    put_u64(&mut out, *version);
                }
            }
            Msg::Entry(entry) => {
                out.push(MSG_ENTRY);
                encode_entry(&mut out, entry);
            }
            Msg::Done { shipped } => {
                out.push(MSG_DONE);
                put_u32(&mut out, *shipped);
            }
        }
        out
    }

    fn decode(payload: &[u8]) -> Result<Msg, String> {
        let mut c = Cursor::new(payload);
        let msg = match c.u8()? {
            MSG_HELLO => Msg::Hello {
                proto: c.u32()?,
                origin: c.str()?,
            },
            MSG_SUMMARY => {
                let n = c.u32()? as usize;
                let mut hwms = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    hwms.push((c.str()?, c.u64()?));
                }
                Msg::Summary { hwms }
            }
            MSG_ENTRY => Msg::Entry(decode_entry(&mut c)?),
            MSG_DONE => Msg::Done { shipped: c.u32()? },
            t => return Err(format!("unknown sync msg tag {t}")),
        };
        c.done()?;
        Ok(msg)
    }
}

fn send(stream: &mut TcpStream, msg: &Msg) -> Result<()> {
    write_frame(stream, &msg.encode()).map_err(|e| anyhow!("sync send: {e}"))
}

fn recv(stream: &mut TcpStream) -> Result<Msg> {
    let payload = read_frame(stream).map_err(|e| anyhow!("sync recv: {e}"))?;
    Msg::decode(&payload).map_err(|e| anyhow!("sync decode: {e}"))
}

// -------------------------------------------------------------- session

/// What one anti-entropy round did, from the local node's perspective.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundReport {
    /// Entries this node shipped to the peer.
    pub shipped: usize,
    /// Remote entries applied locally (won their tiebreaks).
    pub applied: usize,
    /// Remote entries received but stale (lost, or already present).
    pub stale: usize,
}

fn send_delta(
    bridge: &Bridge,
    stream: &mut TcpStream,
    peer_hwms: &HashMap<String, u64>,
) -> Result<usize> {
    let delta = bridge.cache().sync_delta(peer_hwms);
    for entry in &delta {
        send(stream, &Msg::Entry(entry.clone()))?;
    }
    send(
        stream,
        &Msg::Done {
            shipped: delta.len() as u32,
        },
    )?;
    Ok(delta.len())
}

fn recv_delta(bridge: &Bridge, stream: &mut TcpStream) -> Result<(usize, usize)> {
    let (mut applied, mut stale) = (0usize, 0usize);
    loop {
        match recv(stream)? {
            Msg::Entry(entry) => match bridge.cache().apply_sync_entry(entry)? {
                SyncApplied::Applied => applied += 1,
                SyncApplied::Stale => stale += 1,
            },
            Msg::Done { .. } => return Ok((applied, stale)),
            other => bail!("unexpected sync message {other:?} in delta stream"),
        }
    }
}

/// Run one full session on an established connection. `dialer` selects
/// which side of the turn-taking order this node plays.
fn run_session(
    bridge: &Bridge,
    node_id: &str,
    mut stream: TcpStream,
    dialer: bool,
) -> Result<RoundReport> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let _ = stream.set_nodelay(true);
    let hello = Msg::Hello {
        proto: PROTO_VERSION,
        origin: node_id.to_string(),
    };
    let peer_hello = if dialer {
        send(&mut stream, &hello)?;
        recv(&mut stream)?
    } else {
        let h = recv(&mut stream)?;
        send(&mut stream, &hello)?;
        h
    };
    let Msg::Hello { proto, origin } = peer_hello else {
        bail!("peer did not open with HELLO");
    };
    if proto != PROTO_VERSION {
        bail!("peer speaks sync protocol {proto}, this node speaks {PROTO_VERSION}");
    }
    if origin == node_id {
        bail!("peer has this node's id '{origin}' — each node needs a distinct --node-id");
    }
    let summary = Msg::Summary {
        hwms: bridge.cache().sync_hwms().into_iter().collect(),
    };
    let peer_summary = if dialer {
        send(&mut stream, &summary)?;
        recv(&mut stream)?
    } else {
        let s = recv(&mut stream)?;
        send(&mut stream, &summary)?;
        s
    };
    let Msg::Summary { hwms } = peer_summary else {
        bail!("peer did not follow HELLO with SUMMARY");
    };
    let peer_hwms: HashMap<String, u64> = hwms.into_iter().collect();
    let (shipped, applied, stale) = if dialer {
        let (applied, stale) = recv_delta(bridge, &mut stream)?;
        let shipped = send_delta(bridge, &mut stream, &peer_hwms)?;
        (shipped, applied, stale)
    } else {
        let shipped = send_delta(bridge, &mut stream, &peer_hwms)?;
        let (applied, stale) = recv_delta(bridge, &mut stream)?;
        (shipped, applied, stale)
    };
    Ok(RoundReport {
        shipped,
        applied,
        stale,
    })
}

/// Dial `peer` and run one anti-entropy round right now (the
/// `llmbridge sync` one-shot, and the deterministic quiesce the
/// convergence tests use). The bridge must have replication enabled.
pub fn run_once(bridge: &Bridge, peer: &str) -> Result<RoundReport> {
    let node_id = bridge
        .cache()
        .replication_node()
        .ok_or_else(|| anyhow!("replication is off — boot with --node-id"))?
        .to_string();
    let stream = TcpStream::connect(peer).map_err(|e| anyhow!("sync dial {peer}: {e}"))?;
    run_session(bridge, &node_id, stream, true)
}

// -------------------------------------------------------------- service

/// How a [`SyncService`] connects to its fleet.
#[derive(Clone, Debug)]
pub struct SyncConfig {
    /// This node's replication identity (must differ from every peer's).
    pub node_id: String,
    /// Port to accept peer sessions on (`0` = OS-assigned, for tests);
    /// `None` = dial-only node.
    pub listen_port: Option<u16>,
    /// `host:port` of the peer to dial on the anti-entropy cadence;
    /// `None` = accept-only node.
    pub peer: Option<String>,
    /// Anti-entropy cadence for the dialer thread.
    pub interval: Duration,
}

struct Shared {
    bridge: Arc<Bridge>,
    cfg: SyncConfig,
    stop: AtomicBool,
    bound: Mutex<Option<SocketAddr>>,
    last_error: Mutex<Option<String>>,
}

impl Shared {
    fn finish_round(&self, outcome: Result<RoundReport>) {
        let c = &self.bridge.telemetry().counters;
        match outcome {
            Ok(rep) => {
                c.incr("sync_rounds_ok");
                c.add("sync_entries_shipped", rep.shipped as u64);
                c.add("sync_entries_applied", rep.applied as u64);
                c.add("sync_entries_stale", rep.stale as u64);
                *self.last_error.lock().unwrap() = None;
            }
            Err(e) => {
                c.incr("sync_rounds_failed");
                *self.last_error.lock().unwrap() = Some(e.to_string());
            }
        }
    }
}

/// The replication runtime: an accept loop for peer-initiated rounds, a
/// dialer thread on the anti-entropy cadence, or both. Constructed only
/// when the operator configured replication — an unconfigured bridge
/// never starts these threads.
pub struct SyncService {
    shared: Arc<Shared>,
    threads: Vec<JoinHandle<()>>,
}

impl SyncService {
    /// Bind the listener (if configured), then spawn the accept and
    /// dialer threads. Fails fast on a bind error — a mistyped
    /// `--sync-port` should kill boot, not surface rounds later.
    pub fn start(bridge: Arc<Bridge>, cfg: SyncConfig) -> Result<SyncService> {
        let listen = cfg.listen_port;
        let dial = cfg.peer.is_some();
        let shared = Arc::new(Shared {
            bridge,
            cfg,
            stop: AtomicBool::new(false),
            bound: Mutex::new(None),
            last_error: Mutex::new(None),
        });
        let mut threads = Vec::new();
        if let Some(port) = listen {
            let listener = TcpListener::bind(("0.0.0.0", port))
                .map_err(|e| anyhow!("sync listener bind port {port}: {e}"))?;
            *shared.bound.lock().unwrap() = Some(listener.local_addr()?);
            listener.set_nonblocking(true)?;
            let s = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("llmbridge-sync-accept".into())
                    .spawn(move || accept_loop(s, listener))?,
            );
        }
        if dial {
            let s = Arc::clone(&shared);
            threads.push(
                std::thread::Builder::new()
                    .name("llmbridge-sync-dial".into())
                    .spawn(move || dial_loop(s))?,
            );
        }
        Ok(SyncService { shared, threads })
    }

    /// The listener's actual bound address (resolves port 0).
    pub fn listen_addr(&self) -> Option<SocketAddr> {
        *self.shared.bound.lock().unwrap()
    }

    /// Dial the configured peer and run one round synchronously,
    /// counting it like a scheduled round. Tests use this as their
    /// deterministic quiesce instead of waiting out the cadence.
    pub fn run_round_now(&self) -> Result<RoundReport> {
        let peer = self
            .shared
            .cfg
            .peer
            .clone()
            .ok_or_else(|| anyhow!("no --peer configured"))?;
        let outcome = run_once(&self.shared.bridge, &peer);
        let report = match &outcome {
            Ok(r) => Ok(*r),
            Err(e) => Err(anyhow!("{e}")),
        };
        self.shared.finish_round(outcome);
        report
    }

    /// The `/admin/sync` document: identity, wiring, live counters,
    /// per-origin high-water marks, and the last round error if any.
    pub fn status(&self) -> Json {
        status_json(&self.shared)
    }

    /// A cheap cloneable view for the admin router, which outlives no
    /// one and must not own the service's threads.
    pub fn handle(&self) -> SyncHandle {
        SyncHandle {
            shared: Arc::clone(&self.shared),
        }
    }

    /// Signal both threads and join them. Idempotent.
    pub fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for SyncService {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Status-only view of a running [`SyncService`] (see
/// [`SyncService::handle`]); what `GET /admin/sync` reads.
#[derive(Clone)]
pub struct SyncHandle {
    shared: Arc<Shared>,
}

impl SyncHandle {
    /// Same document as [`SyncService::status`].
    pub fn status(&self) -> Json {
        status_json(&self.shared)
    }
}

fn status_json(shared: &Shared) -> Json {
    let c = &shared.bridge.telemetry().counters;
    let cache = shared.bridge.cache();
    let mut hwms: Vec<(String, u64)> = cache.sync_hwms().into_iter().collect();
    hwms.sort();
    Json::obj(vec![
        ("enabled", Json::Bool(true)),
        ("node", Json::str(shared.cfg.node_id.clone())),
        (
            "peer",
            match &shared.cfg.peer {
                Some(p) => Json::str(p.clone()),
                None => Json::Null,
            },
        ),
        (
            "listen",
            match *shared.bound.lock().unwrap() {
                Some(a) => Json::str(a.to_string()),
                None => Json::Null,
            },
        ),
        (
            "interval_ms",
            Json::num(shared.cfg.interval.as_millis() as f64),
        ),
        ("clock", Json::num(cache.replication_clock() as f64)),
        (
            "hwms",
            Json::Obj(
                hwms.into_iter()
                    .map(|(o, v)| (o, Json::num(v as f64)))
                    .collect(),
            ),
        ),
        ("rounds_ok", Json::num(c.get("sync_rounds_ok") as f64)),
        (
            "rounds_failed",
            Json::num(c.get("sync_rounds_failed") as f64),
        ),
        (
            "entries_shipped",
            Json::num(c.get("sync_entries_shipped") as f64),
        ),
        (
            "entries_applied",
            Json::num(c.get("sync_entries_applied") as f64),
        ),
        (
            "entries_stale",
            Json::num(c.get("sync_entries_stale") as f64),
        ),
        (
            "last_error",
            match shared.last_error.lock().unwrap().clone() {
                Some(e) => Json::str(e),
                None => Json::Null,
            },
        ),
    ])
}

fn accept_loop(shared: Arc<Shared>, listener: TcpListener) {
    while !shared.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // The listener is non-blocking (for prompt shutdown);
                // accepted sessions run blocking with per-op timeouts.
                let _ = stream.set_nonblocking(false);
                let outcome =
                    run_session(&shared.bridge, &shared.cfg.node_id, stream, false);
                shared.finish_round(outcome);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(25));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
}

fn dial_loop(shared: Arc<Shared>) {
    let step = Duration::from_millis(25);
    loop {
        // Sleep the cadence in small steps so stop() is prompt.
        let mut slept = Duration::ZERO;
        while slept < shared.cfg.interval {
            if shared.stop.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(step);
            slept += step;
        }
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        if let Some(peer) = shared.cfg.peer.clone() {
            let outcome = run_once(&shared.bridge, &peer);
            shared.finish_round(outcome);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_encode_decode_roundtrip() {
        let msgs = vec![
            Msg::Hello {
                proto: PROTO_VERSION,
                origin: "node-a".into(),
            },
            Msg::Summary {
                hwms: vec![("node-a".into(), 7), ("node-b".into(), 19)],
            },
            Msg::Entry(SyncEntry::Exact {
                key: "what is a wal".into(),
                response: "a log".into(),
                stamp: Stamp {
                    origin: "node-a".into(),
                    version: 3,
                },
            }),
            Msg::Entry(SyncEntry::Tomb {
                key: "stale".into(),
                stamp: Stamp {
                    origin: "node-b".into(),
                    version: 9,
                },
            }),
            Msg::Entry(SyncEntry::Object {
                text: "the cached answer".into(),
                origin: "the prompt".into(),
                is_document: true,
                stamp: Stamp {
                    origin: "node-a".into(),
                    version: 12,
                },
                keys: vec![
                    (CachedType::Prompt, vec![0.25, -0.5, 1.0]),
                    (CachedType::Response, vec![0.0, 0.125, -1.0]),
                ],
            }),
            Msg::Done { shipped: 42 },
        ];
        for m in msgs {
            assert_eq!(Msg::decode(&m.encode()).as_ref(), Ok(&m));
        }
    }

    #[test]
    fn frame_rejects_corruption() {
        // A frame whose checksum is wrong must be rejected by decode of
        // the reader side; simulate via the raw codec.
        let payload = Msg::Done { shipped: 1 }.encode();
        let mut rec = Vec::new();
        rec.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        rec.extend_from_slice(&(fnv1a(&payload) ^ 1).to_le_bytes());
        rec.extend_from_slice(&payload);
        // read_frame needs a TcpStream; exercise the checksum math the
        // same way it does.
        let len = u32::from_le_bytes(rec[0..4].try_into().unwrap()) as usize;
        let crc = u64::from_le_bytes(rec[4..12].try_into().unwrap());
        assert_eq!(len, payload.len());
        assert_ne!(fnv1a(&rec[FRAME_HEADER..]), crc);
    }
}
