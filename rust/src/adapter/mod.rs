//! Model adapter (paper §3.3): a unified *execution* interface over the
//! model pool — the verification cascade and the random-routing baseline
//! it is evaluated against, plus the latency-first combiner from the
//! WhatsApp deployment.
//!
//! Model *choice* — which model(s) a request should run on — lives in
//! [`crate::router`]: the attribute filter ([`PoolFilter`]) and the
//! cascade-role resolver ([`cascade_models`]) are re-exported here for
//! continuity with the paper's adapter framing.

pub mod cascade;

pub use crate::router::{cascade_models, PoolFilter};
pub use cascade::{random_route, Cascade, CascadeResult};
