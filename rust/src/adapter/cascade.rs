//! The verification-based model-selection strategy (paper §3.3) and the
//! random-routing baseline it is benchmarked against (§5.3, Fig 4/5).
//!
//! Protocol: M1 (cheap) answers every prompt; a verifier LLM judges the
//! response on a 1-10 scale with a pre-configured judging prompt; M2
//! (expensive) is consulted only when the verifier's score falls below the
//! threshold.

use std::time::Duration;

use anyhow::Result;

use crate::models::generator::{Completion, Generator};
use crate::models::pricing::ModelId;
use crate::models::quality::{latent_score, verifier_estimate, GenCondition, QueryTraits};
use crate::util::rng::Rng;
use crate::util::seed_of;

/// Cascade configuration.
#[derive(Clone, Copy, Debug)]
pub struct Cascade {
    pub m1: ModelId,
    pub m2: ModelId,
    pub verifier: ModelId,
    pub threshold: f64,
}

/// What the cascade did for one prompt.
#[derive(Clone, Debug)]
pub struct CascadeResult {
    /// The served completion (M1's or M2's).
    pub completion: Completion,
    /// Latent quality of the served response (simulation-only).
    pub latent: f64,
    /// The verifier's 1-10 estimate of M1's answer.
    pub verifier_score: f64,
    /// Whether M2 was consulted.
    pub escalated: bool,
    /// Every real pool call made (M1, verifier, maybe M2), for billing.
    pub calls: Vec<Completion>,
    /// Total latency (sequential: M1 + verifier [+ M2]).
    pub total_latency: Duration,
}

impl Cascade {
    /// Run the cascade for one prompt. `input` is the fully-rendered model
    /// input (context + prompt) for M1/M2; `prompt` is the bare user prompt
    /// the verifier reads (the pre-configured judging prompt sees question
    /// + answer, not the whole context — keeps the verifier's token cost a
    /// small fraction of M2's); `cond` the generation condition for the
    /// quality model.
    pub fn run(
        &self,
        generator: &Generator,
        input: &str,
        prompt: &str,
        traits: &QueryTraits,
        cond: GenCondition,
    ) -> Result<CascadeResult> {
        let mut calls = Vec::new();

        let m1_resp = generator.generate(self.m1, input, None)?;
        let m1_latent = latent_score(traits, self.m1.spec().capability, cond);
        calls.push(m1_resp.clone());

        // The verifier reads prompt + M1 answer + judging instructions and
        // emits a label-sized output.
        let verify_input = format!(
            "judge this answer 1-10. question: {prompt} answer: {}",
            m1_resp.text
        );
        let verifier_call = generator.classify_call(self.verifier, &verify_input)?;
        let vscore =
            verifier_estimate(m1_latent, self.verifier.spec().capability, &traits.id);
        calls.push(verifier_call);

        let (completion, latent, escalated) = if vscore < self.threshold {
            let m2_resp = generator.generate(self.m2, input, None)?;
            let m2_latent = latent_score(traits, self.m2.spec().capability, cond);
            calls.push(m2_resp.clone());
            (m2_resp, m2_latent, true)
        } else {
            (m1_resp, m1_latent, false)
        };

        let total_latency = calls.iter().map(|c| c.latency).sum();
        Ok(CascadeResult {
            completion,
            latent,
            verifier_score: vscore,
            escalated,
            calls,
            total_latency,
        })
    }
}

/// The §5.3 baseline: route to M2 with probability `p`, else M1.
/// Deterministic per (query id, p).
pub fn random_route(
    generator: &Generator,
    m1: ModelId,
    m2: ModelId,
    p: f64,
    input: &str,
    traits: &QueryTraits,
    cond: GenCondition,
) -> Result<CascadeResult> {
    let mut rng = Rng::new(seed_of(&["random-route", &traits.id, &format!("{p:.3}")]));
    let use_m2 = rng.chance(p);
    let model = if use_m2 { m2 } else { m1 };
    let resp = generator.generate(model, input, None)?;
    let latent = latent_score(traits, model.spec().capability, cond);
    let total_latency = resp.latency;
    Ok(CascadeResult {
        completion: resp.clone(),
        latent,
        verifier_score: f64::NAN,
        escalated: use_m2,
        calls: vec![resp],
        total_latency,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cascade_config_construction() {
        let c = Cascade {
            m1: ModelId::Gpt35Turbo,
            m2: ModelId::Gpt4,
            verifier: ModelId::Claude3Opus,
            threshold: 8.0,
        };
        assert!(c.m1.spec().usd_per_mtok_in < c.m2.spec().usd_per_mtok_in);
    }

    // Engine-dependent behaviour is covered in rust/tests/proxy_pipeline.rs.
}
