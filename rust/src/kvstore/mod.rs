//! Key-value store substrate — stand-in for the paper's DynamoDB tables
//! (conversation state, user profiles, leaderboards).
//!
//! Sharded `Mutex<BTreeMap>` segments keyed by FNV of the key, with
//! optional JSON-lines snapshot persistence. Values are [`Json`] documents,
//! mirroring DynamoDB's item model.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::fnv1a;
use crate::util::json::Json;

const SHARDS: usize = 16;

pub struct KvStore {
    shards: Vec<Mutex<BTreeMap<String, Json>>>,
}

impl Default for KvStore {
    fn default() -> Self {
        Self::new()
    }
}

impl KvStore {
    pub fn new() -> KvStore {
        KvStore {
            shards: (0..SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<BTreeMap<String, Json>> {
        &self.shards[(fnv1a(key.as_bytes()) as usize) % SHARDS]
    }

    pub fn put(&self, key: &str, value: Json) {
        self.shard(key).lock().unwrap().insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> Option<Json> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    pub fn delete(&self, key: &str) -> bool {
        self.shard(key).lock().unwrap().remove(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read-modify-write under the shard lock (DynamoDB UpdateItem analog).
    pub fn update(&self, key: &str, f: impl FnOnce(Option<Json>) -> Json) -> Json {
        let mut shard = self.shard(key).lock().unwrap();
        let old = shard.get(key).cloned();
        let new = f(old);
        shard.insert(key.to_string(), new.clone());
        new
    }

    /// All keys with the given prefix (DynamoDB Query on a key prefix).
    pub fn scan_prefix(&self, prefix: &str) -> Vec<(String, Json)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let m = s.lock().unwrap();
            for (k, v) in m.range(prefix.to_string()..) {
                if !k.starts_with(prefix) {
                    break;
                }
                out.push((k.clone(), v.clone()));
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// One FNV hash over key + NUL + value: binding the pair into a single
    /// hash means swapping values between keys changes the entry hashes (a
    /// per-part XOR would cancel under that permutation).
    fn entry_hash(k: &str, v: &str) -> u64 {
        let mut buf = Vec::with_capacity(k.len() + 1 + v.len());
        buf.extend_from_slice(k.as_bytes());
        buf.push(0);
        buf.extend_from_slice(v.as_bytes());
        fnv1a(&buf)
    }

    /// Persist as JSON-lines: one `{"k":...,"v":...}` per line, fsynced
    /// (snapshots participate in the persist layer's crash-safety story).
    ///
    /// Returns the `(len, checksum)` of **exactly the rows written** —
    /// computed under the same shard locks as the writes, so a manifest
    /// built from the return value always validates against the file even
    /// if other threads mutate the store mid-snapshot.
    pub fn snapshot(&self, path: &Path) -> Result<(usize, u64)> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("snapshot create {path:?}"))?;
        let mut w = std::io::BufWriter::new(f);
        let mut len = 0usize;
        let mut checksum = 0u64;
        for s in &self.shards {
            let m = s.lock().unwrap();
            for (k, v) in m.iter() {
                let line = Json::obj(vec![("k", Json::str(k.clone())), ("v", v.clone())]);
                writeln!(w, "{}", line.to_string())?;
                len += 1;
                checksum ^= Self::entry_hash(k, &v.to_string());
            }
        }
        let f = w.into_inner().context("snapshot flush")?;
        f.sync_all().context("snapshot sync")?;
        Ok((len, checksum))
    }

    /// Order-independent content checksum: XOR of per-entry hashes, each
    /// binding key to value (see `KvStore::entry_hash`). Recorded in the
    /// snapshot MANIFEST by [`KvStore::snapshot`] and cross-checked against
    /// the restored store on boot.
    pub fn checksum(&self) -> u64 {
        let mut acc = 0u64;
        for s in &self.shards {
            let m = s.lock().unwrap();
            for (k, v) in m.iter() {
                acc ^= Self::entry_hash(k, &v.to_string());
            }
        }
        acc
    }

    pub fn restore(path: &Path) -> Result<KvStore> {
        use std::io::BufRead as _;
        let store = KvStore::new();
        let f = std::fs::File::open(path)
            .with_context(|| format!("snapshot read {path:?}"))?;
        // Stream line-by-line: months of history must not be held as one
        // String alongside the parsed rows during boot.
        for line in std::io::BufReader::new(f).lines() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let row = Json::parse(&line)?;
            let k = row.str_of("k")?;
            let v = row.req("v")?.clone();
            store.put(&k, v);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_text};

    #[test]
    fn put_get_delete() {
        let kv = KvStore::new();
        kv.put("user:1", Json::num(5.0));
        assert_eq!(kv.get("user:1"), Some(Json::num(5.0)));
        assert!(kv.delete("user:1"));
        assert!(!kv.delete("user:1"));
        assert_eq!(kv.get("user:1"), None);
    }

    #[test]
    fn update_read_modify_write() {
        let kv = KvStore::new();
        for _ in 0..5 {
            kv.update("ctr", |old| {
                Json::num(old.and_then(|j| j.as_f64()).unwrap_or(0.0) + 1.0)
            });
        }
        assert_eq!(kv.get("ctr"), Some(Json::num(5.0)));
    }

    #[test]
    fn scan_prefix_sorted() {
        let kv = KvStore::new();
        kv.put("conv:b:2", Json::num(2.0));
        kv.put("conv:a:1", Json::num(1.0));
        kv.put("other:z", Json::num(9.0));
        let rows = kv.scan_prefix("conv:");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "conv:a:1");
    }

    #[test]
    fn snapshot_roundtrip() {
        let kv = KvStore::new();
        kv.put("a", Json::str("x\ny"));
        kv.put("b", Json::Arr(vec![Json::num(1.0), Json::Null]));
        let dir = std::env::temp_dir().join("llmbridge_kv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.jsonl");
        let (len, checksum) = kv.snapshot(&path).unwrap();
        assert_eq!(len, 2);
        let back = KvStore::restore(&path).unwrap();
        assert_eq!(back.get("a"), Some(Json::str("x\ny")));
        assert_eq!(back.len(), 2);
        // The restored store hashes identically to the rows as written
        // (this is exactly the manifest validation on boot).
        assert_eq!(back.checksum(), checksum);
        assert_eq!(kv.checksum(), checksum);
    }

    #[test]
    fn checksum_tracks_content_not_order() {
        let a = KvStore::new();
        a.put("x", Json::num(1.0));
        a.put("y", Json::num(2.0));
        let b = KvStore::new();
        b.put("y", Json::num(2.0));
        b.put("x", Json::num(1.0));
        assert_eq!(a.checksum(), b.checksum());
        b.put("y", Json::num(3.0));
        assert_ne!(a.checksum(), b.checksum());
        assert_ne!(KvStore::new().checksum(), a.checksum());
        // Swapping values between keys must NOT cancel: each entry hash
        // binds key to value.
        let swapped = KvStore::new();
        swapped.put("x", Json::num(2.0));
        swapped.put("y", Json::num(1.0));
        assert_ne!(a.checksum(), swapped.checksum());
    }

    #[test]
    fn prop_roundtrip_arbitrary_keys() {
        let kv = KvStore::new();
        forall(
            11,
            200,
            |r| (gen_text(r, 4), gen_text(r, 8)),
            |(k, v)| {
                kv.put(k, Json::str(v.clone()));
                kv.get(k).and_then(|j| j.as_str().map(|s| s.to_string()))
                    == Some(v.clone())
            },
        );
    }

    #[test]
    fn concurrent_updates_consistent() {
        use std::sync::Arc;
        let kv = Arc::new(KvStore::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let kv = kv.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    kv.update("ctr", |old| {
                        Json::num(old.and_then(|j| j.as_f64()).unwrap_or(0.0) + 1.0)
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kv.get("ctr"), Some(Json::num(800.0)));
    }
}
