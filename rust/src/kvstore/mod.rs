//! Key-value store substrate — stand-in for the paper's DynamoDB tables
//! (conversation state, user profiles, leaderboards).
//!
//! Sharded `Mutex<BTreeMap>` segments keyed by FNV of the key, with
//! optional JSON-lines snapshot persistence. Values are [`Json`] documents,
//! mirroring DynamoDB's item model.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::util::fnv1a;
use crate::util::json::Json;

const SHARDS: usize = 16;

pub struct KvStore {
    shards: Vec<Mutex<BTreeMap<String, Json>>>,
}

impl Default for KvStore {
    fn default() -> Self {
        Self::new()
    }
}

impl KvStore {
    pub fn new() -> KvStore {
        KvStore {
            shards: (0..SHARDS).map(|_| Mutex::new(BTreeMap::new())).collect(),
        }
    }

    fn shard(&self, key: &str) -> &Mutex<BTreeMap<String, Json>> {
        &self.shards[(fnv1a(key.as_bytes()) as usize) % SHARDS]
    }

    pub fn put(&self, key: &str, value: Json) {
        self.shard(key).lock().unwrap().insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> Option<Json> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    pub fn delete(&self, key: &str) -> bool {
        self.shard(key).lock().unwrap().remove(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Read-modify-write under the shard lock (DynamoDB UpdateItem analog).
    pub fn update(&self, key: &str, f: impl FnOnce(Option<Json>) -> Json) -> Json {
        let mut shard = self.shard(key).lock().unwrap();
        let old = shard.get(key).cloned();
        let new = f(old);
        shard.insert(key.to_string(), new.clone());
        new
    }

    /// All keys with the given prefix (DynamoDB Query on a key prefix).
    pub fn scan_prefix(&self, prefix: &str) -> Vec<(String, Json)> {
        let mut out = Vec::new();
        for s in &self.shards {
            let m = s.lock().unwrap();
            for (k, v) in m.range(prefix.to_string()..) {
                if !k.starts_with(prefix) {
                    break;
                }
                out.push((k.clone(), v.clone()));
            }
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Persist as JSON-lines: one `{"k":...,"v":...}` per line.
    pub fn snapshot(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("snapshot create {path:?}"))?;
        for s in &self.shards {
            let m = s.lock().unwrap();
            for (k, v) in m.iter() {
                let line = Json::obj(vec![("k", Json::str(k.clone())), ("v", v.clone())]);
                writeln!(f, "{}", line.to_string())?;
            }
        }
        Ok(())
    }

    pub fn restore(path: &Path) -> Result<KvStore> {
        let store = KvStore::new();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("snapshot read {path:?}"))?;
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let row = Json::parse(line)?;
            let k = row.str_of("k")?;
            let v = row.req("v")?.clone();
            store.put(&k, v);
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_text};

    #[test]
    fn put_get_delete() {
        let kv = KvStore::new();
        kv.put("user:1", Json::num(5.0));
        assert_eq!(kv.get("user:1"), Some(Json::num(5.0)));
        assert!(kv.delete("user:1"));
        assert!(!kv.delete("user:1"));
        assert_eq!(kv.get("user:1"), None);
    }

    #[test]
    fn update_read_modify_write() {
        let kv = KvStore::new();
        for _ in 0..5 {
            kv.update("ctr", |old| {
                Json::num(old.and_then(|j| j.as_f64()).unwrap_or(0.0) + 1.0)
            });
        }
        assert_eq!(kv.get("ctr"), Some(Json::num(5.0)));
    }

    #[test]
    fn scan_prefix_sorted() {
        let kv = KvStore::new();
        kv.put("conv:b:2", Json::num(2.0));
        kv.put("conv:a:1", Json::num(1.0));
        kv.put("other:z", Json::num(9.0));
        let rows = kv.scan_prefix("conv:");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "conv:a:1");
    }

    #[test]
    fn snapshot_roundtrip() {
        let kv = KvStore::new();
        kv.put("a", Json::str("x\ny"));
        kv.put("b", Json::Arr(vec![Json::num(1.0), Json::Null]));
        let dir = std::env::temp_dir().join("llmbridge_kv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.jsonl");
        kv.snapshot(&path).unwrap();
        let back = KvStore::restore(&path).unwrap();
        assert_eq!(back.get("a"), Some(Json::str("x\ny")));
        assert_eq!(back.len(), 2);
    }

    #[test]
    fn prop_roundtrip_arbitrary_keys() {
        let kv = KvStore::new();
        forall(
            11,
            200,
            |r| (gen_text(r, 4), gen_text(r, 8)),
            |(k, v)| {
                kv.put(k, Json::str(v.clone()));
                kv.get(k).and_then(|j| j.as_str().map(|s| s.to_string()))
                    == Some(v.clone())
            },
        );
    }

    #[test]
    fn concurrent_updates_consistent() {
        use std::sync::Arc;
        let kv = Arc::new(KvStore::new());
        let mut handles = vec![];
        for _ in 0..8 {
            let kv = kv.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    kv.update("ctr", |old| {
                        Json::num(old.and_then(|j| j.as_f64()).unwrap_or(0.0) + 1.0)
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(kv.get("ctr"), Some(Json::num(800.0)));
    }
}
