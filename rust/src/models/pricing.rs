//! The model pool: identities, price table, capability scores and artifact
//! bindings for every LLM LLMBridge proxies to.
//!
//! Prices mirror the public per-token price *ratios* the paper relies on
//! (GPT-4 ≈ 60× GPT-3.5 input; output ≈ 2-5× input; GPT-4-class ≈ 200× a
//! 4o-mini-class model), and capabilities are the calibrated latent scores
//! the quality model consumes (DESIGN.md §Quality-model calibration).

use std::fmt;

use anyhow::{bail, Result};

/// Stable model identifier (the paper's pool, §4 + §5).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelId {
    Gpt35Turbo,
    Gpt4,
    Gpt4o,
    Gpt4oMini,
    Claude3Opus,
    Claude3Haiku,
    Phi3Mini,
    Llama38b,
    Gemini20Flash,
    SonarHugeOnline,
}

impl ModelId {
    pub fn as_str(&self) -> &'static str {
        match self {
            ModelId::Gpt35Turbo => "gpt-3.5-turbo",
            ModelId::Gpt4 => "gpt-4",
            ModelId::Gpt4o => "gpt-4o",
            ModelId::Gpt4oMini => "gpt-4o-mini",
            ModelId::Claude3Opus => "claude-3-opus",
            ModelId::Claude3Haiku => "claude-3-haiku",
            ModelId::Phi3Mini => "phi-3-mini",
            ModelId::Llama38b => "llama-3-8b",
            ModelId::Gemini20Flash => "gemini-2.0-flash",
            ModelId::SonarHugeOnline => "sonar-huge-online",
        }
    }

    pub fn parse(s: &str) -> Result<ModelId> {
        for spec in POOL {
            if spec.id.as_str() == s {
                return Ok(spec.id);
            }
        }
        bail!("unknown model id '{s}'")
    }

    pub fn spec(&self) -> &'static ModelSpec {
        POOL.iter().find(|m| m.id == *self).expect("pool covers all ids")
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Model generation, used by the §5.3 "old vs new models" experiments.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Generation {
    Old,
    New,
}

/// Latency class for telemetry bucketing (§5.1: large models mean 3.8s,
/// small 1.2s).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LatencyClass {
    Small,
    Large,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub id: ModelId,
    pub family: &'static str,
    pub generation: Generation,
    /// Which AOT artifact serves this pool entry.
    pub artifact: &'static str,
    /// Latent capability in [0,1] — input to the quality model.
    pub capability: f64,
    /// USD per 1M input tokens.
    pub usd_per_mtok_in: f64,
    /// USD per 1M output tokens.
    pub usd_per_mtok_out: f64,
    /// Nominal (billable) context window in tokens.
    pub context_window: u64,
    /// Default generation budget in tokens (bigger models answer longer).
    pub default_max_new: usize,
    pub latency_class: LatencyClass,
    /// Produces grounded citations (the §5.1 Gemini anecdote).
    pub grounded_citations: bool,
}

pub const POOL: &[ModelSpec] = &[
    ModelSpec {
        id: ModelId::Gpt35Turbo,
        family: "openai",
        generation: Generation::Old,
        artifact: "mini",
        capability: 0.55,
        usd_per_mtok_in: 0.50,
        usd_per_mtok_out: 1.50,
        context_window: 16_385,
        default_max_new: 10,
        latency_class: LatencyClass::Large,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Gpt4,
        family: "openai",
        generation: Generation::Old,
        artifact: "large",
        capability: 0.88,
        usd_per_mtok_in: 30.0,
        usd_per_mtok_out: 60.0,
        context_window: 8_192,
        default_max_new: 28,
        latency_class: LatencyClass::Large,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Gpt4o,
        family: "openai",
        generation: Generation::New,
        artifact: "large",
        capability: 0.92,
        usd_per_mtok_in: 2.50,
        usd_per_mtok_out: 10.0,
        context_window: 128_000,
        default_max_new: 20,
        latency_class: LatencyClass::Large,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Gpt4oMini,
        family: "openai",
        generation: Generation::New,
        artifact: "mini",
        capability: 0.78,
        usd_per_mtok_in: 0.15,
        usd_per_mtok_out: 0.60,
        context_window: 128_000,
        default_max_new: 14,
        latency_class: LatencyClass::Small,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Claude3Opus,
        family: "anthropic",
        generation: Generation::Old,
        artifact: "large",
        capability: 0.85,
        usd_per_mtok_in: 15.0,
        usd_per_mtok_out: 75.0,
        context_window: 200_000,
        default_max_new: 20,
        latency_class: LatencyClass::Large,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Claude3Haiku,
        family: "anthropic",
        generation: Generation::New,
        artifact: "nano",
        capability: 0.60,
        usd_per_mtok_in: 0.25,
        usd_per_mtok_out: 1.25,
        context_window: 200_000,
        default_max_new: 10,
        latency_class: LatencyClass::Small,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Phi3Mini,
        family: "azure",
        generation: Generation::New,
        artifact: "nano",
        capability: 0.45,
        usd_per_mtok_in: 0.10,
        usd_per_mtok_out: 0.30,
        context_window: 4_096,
        default_max_new: 10,
        latency_class: LatencyClass::Small,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Llama38b,
        family: "meta",
        generation: Generation::New,
        artifact: "mini",
        capability: 0.65,
        usd_per_mtok_in: 0.20,
        usd_per_mtok_out: 0.60,
        context_window: 8_192,
        default_max_new: 14,
        latency_class: LatencyClass::Small,
        grounded_citations: false,
    },
    ModelSpec {
        id: ModelId::Gemini20Flash,
        family: "google",
        generation: Generation::New,
        artifact: "mini",
        capability: 0.80,
        usd_per_mtok_in: 0.10,
        usd_per_mtok_out: 0.40,
        context_window: 1_000_000,
        default_max_new: 14,
        latency_class: LatencyClass::Small,
        grounded_citations: true,
    },
    ModelSpec {
        id: ModelId::SonarHugeOnline,
        family: "perplexity",
        generation: Generation::New,
        artifact: "large",
        capability: 0.97,
        usd_per_mtok_in: 5.0,
        usd_per_mtok_out: 5.0,
        context_window: 128_000,
        default_max_new: 24,
        latency_class: LatencyClass::Large,
        grounded_citations: true,
    },
];

/// Cost in USD for a single call.
pub fn call_cost(model: ModelId, input_tokens: u64, output_tokens: u64) -> f64 {
    let spec = model.spec();
    input_tokens as f64 * spec.usd_per_mtok_in / 1e6
        + output_tokens as f64 * spec.usd_per_mtok_out / 1e6
}

// ------------------------------------------------------- scoring helpers
// Used by the routing policies (`crate::router`): pool scans scored by the
// price/capability columns above. Tie-breaking is part of the contract —
// `min_by` keeps the *first* of equal entries, `max_by` the *last* — so
// policies inherit a deterministic pick from the POOL ordering.

/// Pool entries belonging to one model generation.
pub fn pool_in(generation: Generation) -> impl Iterator<Item = &'static ModelSpec> {
    POOL.iter().filter(move |m| m.generation == generation)
}

/// Cheapest entry by input price (ties keep the first). The single
/// price-scan implementation every selection path shares.
pub fn min_price_of<'a>(specs: impl IntoIterator<Item = &'a ModelSpec>) -> Option<ModelId> {
    specs
        .into_iter()
        .min_by(|a, b| a.usd_per_mtok_in.partial_cmp(&b.usd_per_mtok_in).unwrap())
        .map(|m| m.id)
}

/// Most expensive entry by input price (ties keep the last).
pub fn max_price_of<'a>(specs: impl IntoIterator<Item = &'a ModelSpec>) -> Option<ModelId> {
    specs
        .into_iter()
        .max_by(|a, b| a.usd_per_mtok_in.partial_cmp(&b.usd_per_mtok_in).unwrap())
        .map(|m| m.id)
}

/// Cheapest model by input price within a generation (§3.2 "cost").
pub fn cheapest_in(generation: Generation) -> Option<ModelId> {
    min_price_of(pool_in(generation))
}

/// Most expensive model by input price within a generation (§3.2
/// "quality": "the most expensive model").
pub fn priciest_in(generation: Generation) -> Option<ModelId> {
    max_price_of(pool_in(generation))
}

/// The generation's default "big" model — the escalation target §3.2/§3.3
/// route regenerations to ("directly route the prompt to the more
/// expensive LLM").
pub fn flagship(generation: Generation) -> ModelId {
    match generation {
        Generation::Old => ModelId::Gpt4,
        Generation::New => ModelId::Gpt4o,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_covers_all_ids_uniquely() {
        let mut seen = std::collections::HashSet::new();
        for spec in POOL {
            assert!(seen.insert(spec.id), "duplicate {id}", id = spec.id);
            assert!((0.0..=1.0).contains(&spec.capability));
            assert!(spec.usd_per_mtok_out >= spec.usd_per_mtok_in);
        }
        assert_eq!(POOL.len(), 10);
    }

    #[test]
    fn paper_price_ratios_hold() {
        // GPT-4 input is 60x GPT-3.5 (paper: prices vary >300x across pool).
        let r = ModelId::Gpt4.spec().usd_per_mtok_in
            / ModelId::Gpt35Turbo.spec().usd_per_mtok_in;
        assert!((r - 60.0).abs() < 1.0);
        // GPT-4 is 200x GPT-4o-mini input (paper cites GPT-4.5 at 250x).
        let r2 = ModelId::Gpt4.spec().usd_per_mtok_in
            / ModelId::Gpt4oMini.spec().usd_per_mtok_in;
        assert!(r2 >= 150.0, "ratio={r2}");
        // Max/min across pool > 100x.
        let max = POOL.iter().map(|m| m.usd_per_mtok_in).fold(0.0, f64::max);
        let min = POOL
            .iter()
            .map(|m| m.usd_per_mtok_in)
            .fold(f64::INFINITY, f64::min);
        assert!(max / min >= 100.0);
    }

    #[test]
    fn call_cost_math() {
        // 1000 in + 100 out on gpt-4: 1000*30/1e6 + 100*60/1e6 = 0.036.
        assert!((call_cost(ModelId::Gpt4, 1000, 100) - 0.036).abs() < 1e-9);
    }

    #[test]
    fn scoring_helpers_pick_price_extremes() {
        assert_eq!(cheapest_in(Generation::Old), Some(ModelId::Gpt35Turbo));
        assert_eq!(priciest_in(Generation::Old), Some(ModelId::Gpt4));
        // New generation has a 0.10 price tie (Phi-3 vs Gemini Flash);
        // min_by keeps the first POOL entry.
        assert_eq!(cheapest_in(Generation::New), Some(ModelId::Phi3Mini));
        assert_eq!(priciest_in(Generation::New), Some(ModelId::SonarHugeOnline));
        assert_eq!(flagship(Generation::Old), ModelId::Gpt4);
        assert_eq!(flagship(Generation::New), ModelId::Gpt4o);
    }

    #[test]
    fn parse_roundtrip() {
        for spec in POOL {
            assert_eq!(ModelId::parse(spec.id.as_str()).unwrap(), spec.id);
        }
        assert!(ModelId::parse("gpt-99").is_err());
    }
}
