//! The model layer: pool definitions and pricing, the PJRT-backed
//! generator, the latent quality model (the documented simulation
//! substitution), and the LLM-as-judge used by the §5.3 benchmarks.

pub mod generator;
pub mod judge;
pub mod pricing;
pub mod quality;

pub use generator::{Completion, Generator};
pub use judge::Judge;
pub use pricing::{ModelId, ModelSpec, POOL};
pub use quality::{GenCondition, QueryTraits};
