//! LLM-as-a-judge (inspired by MT-Bench, the paper's §5.3 protocol):
//! score a response 0-10 against a reference answer, averaging several
//! judge runs exactly as the paper does ("averaged over four runs").
//!
//! The score combines the latent quality gap (the substitution for the
//! judge model's semantic assessment) with the *measured* embedding
//! similarity between response and reference texts — real artifact
//! executions on the request path.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::Result;

use super::quality::calib;
use crate::runtime::EngineHandle;
use crate::util::fnv1a;
use crate::util::rng::Rng;
use crate::util::seed_of;
use crate::vecdb::Metric;

pub struct Judge {
    engine: EngineHandle,
    /// Number of judge runs to average (paper: 3-4).
    pub runs: u32,
    /// Embedding memo: figure replays judge the same reference text against
    /// many candidates (perf pass, EXPERIMENTS.md §Perf).
    embed_memo: Mutex<HashMap<u64, Vec<f32>>>,
}

impl Judge {
    pub fn new(engine: EngineHandle) -> Judge {
        Judge {
            engine,
            runs: 4,
            embed_memo: Mutex::new(HashMap::new()),
        }
    }

    fn embed_cached(&self, text: &str) -> Result<Vec<f32>> {
        let key = fnv1a(text.as_bytes());
        if let Some(v) = self.embed_memo.lock().unwrap().get(&key) {
            return Ok(v.clone());
        }
        let v = self.engine.embed_text(text)?;
        let mut memo = self.embed_memo.lock().unwrap();
        if memo.len() < 100_000 {
            memo.insert(key, v.clone());
        }
        Ok(v)
    }

    /// Cosine similarity between two texts via the embedder artifact.
    pub fn embed_similarity(&self, a: &str, b: &str) -> Result<f64> {
        if a.is_empty() || b.is_empty() {
            return Ok(0.0);
        }
        let ea = self.embed_cached(a)?;
        let eb = self.embed_cached(b)?;
        Ok(Metric::Cosine.score(&ea, &eb) as f64)
    }

    /// Judge a response against a reference. `resp_latent` / `ref_latent`
    /// are the latent quality scores of the two generations; the reference
    /// scores 10 by construction (§5.3: "the response from M2 is assumed as
    /// the reference, and hence always gets a score of 10").
    pub fn score(
        &self,
        query_id: &str,
        resp_text: &str,
        resp_latent: f64,
        ref_text: &str,
        ref_latent: f64,
    ) -> Result<f64> {
        let sim = self.embed_similarity(resp_text, ref_text)?;
        Ok(self.score_with_sim(query_id, resp_latent, ref_latent, sim))
    }

    /// Pure scoring given a pre-computed similarity (used by replay paths
    /// that batch their embedding calls).
    pub fn score_with_sim(
        &self,
        query_id: &str,
        resp_latent: f64,
        ref_latent: f64,
        emb_sim: f64,
    ) -> f64 {
        let gap = (ref_latent - resp_latent).max(0.0);
        let base = 10.0 - gap - calib::JUDGE_SIM_W * (1.0 - emb_sim.clamp(0.0, 1.0));
        let mut total = 0.0;
        for run in 0..self.runs {
            let mut rng =
                Rng::new(seed_of(&["judge", query_id, &run.to_string()]));
            total += (base + rng.normal_ms(0.0, calib::JUDGE_NOISE_SD)).clamp(0.0, 10.0);
        }
        total / self.runs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    // Pure-scoring tests (no engine needed).
    fn dummy_judge() -> JudgeNoEngine {
        JudgeNoEngine { runs: 4 }
    }

    /// Engine-free shim exposing the same scoring math for unit tests.
    struct JudgeNoEngine {
        runs: u32,
    }

    impl JudgeNoEngine {
        fn score_with_sim(&self, query_id: &str, resp: f64, reference: f64, sim: f64) -> f64 {
            let gap = (reference - resp).max(0.0);
            let base = 10.0 - gap - calib::JUDGE_SIM_W * (1.0 - sim.clamp(0.0, 1.0));
            let mut total = 0.0;
            for run in 0..self.runs {
                let mut rng = Rng::new(seed_of(&["judge", query_id, &run.to_string()]));
                total += (base + rng.normal_ms(0.0, calib::JUDGE_NOISE_SD)).clamp(0.0, 10.0);
            }
            total / self.runs as f64
        }
    }

    #[test]
    fn reference_scores_ten_ish() {
        let j = dummy_judge();
        let s = j.score_with_sim("q1", 9.0, 9.0, 1.0);
        assert!(s > 9.0, "s={s}");
    }

    #[test]
    fn larger_gap_lower_score() {
        let j = dummy_judge();
        let good = j.score_with_sim("q2", 8.5, 9.0, 0.8);
        let bad = j.score_with_sim("q2", 4.0, 9.0, 0.8);
        assert!(good > bad + 3.0);
    }

    #[test]
    fn similarity_contributes() {
        let j = dummy_judge();
        let close = j.score_with_sim("q3", 7.0, 9.0, 1.0);
        let far = j.score_with_sim("q3", 7.0, 9.0, 0.0);
        assert!(close > far);
        assert!((close - far - calib::JUDGE_SIM_W).abs() < 1e-9);
    }

    #[test]
    fn averaging_reduces_variance() {
        // With the same base inputs, a 4-run average must be closer to the
        // noise-free base than the worst single run, across many queries.
        let one = JudgeNoEngine { runs: 1 };
        let four = JudgeNoEngine { runs: 4 };
        let mut dev1 = 0.0;
        let mut dev4 = 0.0;
        for i in 0..300 {
            let base = 10.0 - 1.5 - calib::JUDGE_SIM_W * 0.2;
            let id = format!("qa{i}");
            dev1 += (one.score_with_sim(&id, 8.5, 10.0, 0.8) - base).abs();
            dev4 += (four.score_with_sim(&id, 8.5, 10.0, 0.8) - base).abs();
        }
        assert!(dev4 < dev1, "dev4={dev4} dev1={dev1}");
    }
}
