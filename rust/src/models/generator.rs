//! Generator: turns a pool model + input text into a completion by driving
//! the PJRT decode loop (prefill window, per-token `lm_step` execution,
//! top-k temperature sampling) — the real compute on the request path.
//!
//! Cost accounting matches the paper's billing model: input tokens are
//! counted *pre-truncation* (the artifact window is a sliding context
//! window; see DESIGN.md §Substitutions), output tokens are the tokens
//! actually generated, and USD cost comes from the
//! [`pricing`](crate::models::pricing) table.
//!
//! A memo table caches completions by (model, input) hash: generation is
//! deterministic per (model, input), so replays — the §5.3 benchmarks
//! replay the same 244-query workload under many strategies — skip
//! redundant PJRT work while still reporting the originally measured
//! latency. Disable with `memoize = false`.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::pricing::{call_cost, ModelId};
use crate::runtime::{tokenizer, EngineHandle};
use crate::util::rng::Rng;
use crate::util::{fnv1a, seed_of};

/// Result of one LLM call.
#[derive(Clone, Debug)]
pub struct Completion {
    pub model: ModelId,
    pub text: String,
    pub input_tokens: u64,
    pub output_tokens: u64,
    /// Wall-clock of the original PJRT execution (preserved on memo hits).
    pub latency: Duration,
    pub cost_usd: f64,
    pub from_memo: bool,
}

pub struct Generator {
    engine: EngineHandle,
    memo: Mutex<HashMap<u64, Completion>>,
    pub memoize: bool,
    temperature: f32,
    top_k: usize,
}

impl Generator {
    pub fn new(engine: EngineHandle) -> Generator {
        Generator {
            engine,
            memo: Mutex::new(HashMap::new()),
            memoize: true,
            temperature: 0.9,
            top_k: 40,
        }
    }

    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }

    /// Sample one token id from logits (top-k, temperature, seeded).
    fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        let k = self.top_k.min(logits.len());
        // Indices of the top-k logits.
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            logits[b].partial_cmp(&logits[a]).unwrap()
        });
        idx.truncate(k);
        let max = idx.iter().map(|&i| logits[i]).fold(f32::MIN, f32::max);
        let weights: Vec<f64> = idx
            .iter()
            .map(|&i| (((logits[i] - max) / self.temperature) as f64).exp())
            .collect();
        let total: f64 = weights.iter().sum();
        let mut pick = rng.f64() * total;
        for (j, w) in weights.iter().enumerate() {
            pick -= w;
            if pick <= 0.0 {
                return idx[j] as i32;
            }
        }
        idx[k - 1] as i32
    }

    /// Run one completion. `max_new` defaults to the model's configured
    /// generation budget.
    pub fn generate(
        &self,
        model: ModelId,
        input_text: &str,
        max_new: Option<usize>,
    ) -> Result<Completion> {
        let spec = model.spec();
        let max_new = max_new.unwrap_or(spec.default_max_new).max(1);
        let memo_key = fnv1a(
            format!("{}|{}|{}", model.as_str(), max_new, input_text).as_bytes(),
        );
        if self.memoize {
            if let Some(hit) = self.memo.lock().unwrap().get(&memo_key) {
                let mut c = hit.clone();
                c.from_memo = true;
                return Ok(c);
            }
        }

        let seq_len = self.engine.seq_len();
        let input_tokens = tokenizer::count_tokens(input_text)
            .min(spec.context_window);
        let (mut tokens, mut live) =
            tokenizer::gen_prefix(input_text, seq_len, max_new.min(seq_len / 2));
        let mut rng = Rng::new(seed_of(&["gen", model.as_str(), input_text]));

        let start = Instant::now();
        let mut generated: Vec<i32> = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            if (live as usize) >= seq_len {
                break;
            }
            let logits = self
                .engine
                .lm_logits(spec.artifact, tokens.clone(), live)?;
            let next = self.sample(&logits, &mut rng);
            generated.push(next);
            tokens[live as usize] = next;
            live += 1;
            if next == tokenizer::EOS {
                break;
            }
        }
        let latency = start.elapsed();
        let output_tokens = generated.len().max(1) as u64;
        let completion = Completion {
            model,
            text: tokenizer::detokenize(&generated),
            input_tokens,
            output_tokens,
            latency,
            cost_usd: call_cost(model, input_tokens, output_tokens),
            from_memo: false,
        };
        if self.memoize {
            let mut memo = self.memo.lock().unwrap();
            if memo.len() < 200_000 {
                memo.insert(memo_key, completion.clone());
            }
        }
        Ok(completion)
    }

    /// A short classification-style call (single output token — "we keep
    /// the number of output tokens of the intermediate LLM call small",
    /// §5.3) — used by the SmartContext / SmartCache / verifier delegation
    /// paths where the answer is a label, not prose.
    pub fn classify_call(&self, model: ModelId, input_text: &str) -> Result<Completion> {
        self.generate(model, input_text, Some(1))
    }

    pub fn memo_len(&self) -> usize {
        self.memo.lock().unwrap().len()
    }
}
