//! Latent quality model — the documented substitution for "GPT-4 answers
//! better than GPT-3.5" (DESIGN.md §Substitutions).
//!
//! Tiny random-weight transformers produce text with no meaningful quality
//! ordering, but every figure in §5.3 is a *score distribution* conditioned
//! on routing/caching/context decisions. This module assigns each response
//! a latent 0-10 score from the factors the paper identifies:
//!
//! * model **capability** vs. query **difficulty** (model selection, Fig 4),
//! * **context sufficiency** for history-dependent queries (SmartContext,
//!   Figs 1b/6b: "difference is most evident only in the tail 20%"),
//! * **grounding** from cached facts vs. small-model hallucination
//!   (SmartCache, Fig 7: worst case 4pts grounded vs 1pt hallucinated).
//!
//! All noise is seeded from stable (query, model, stage) hashes, so entire
//! benchmark runs are bit-reproducible. Calibration constants live in
//! [`calib`] and are pinned by tests that assert the paper's operating
//! points (e.g. verifier-t=8 routes >60% of prompts to M2 with old models,
//! ~25% with new ones).

use crate::util::rng::Rng;
use crate::util::seed_of;

/// Calibration constants (see DESIGN.md §Quality-model calibration).
pub mod calib {
    /// Logit offset: a capability == difficulty match lands near 7.
    pub const S0: f64 = 0.85;
    /// Logit slope on (capability - difficulty).
    pub const S1: f64 = 4.0;
    /// Logit penalty for missing required context.
    pub const CTX_W: f64 = 2.8;
    /// Latent score noise (per response).
    pub const NOISE_SD: f64 = 0.55;
    /// Hallucination: low-capability models on factual queries without
    /// grounding collapse to this band (Fig 7a worst case ≈ 1pt).
    pub const HALLU_CAP_THRESHOLD: f64 = 0.75;
    pub const HALLU_BASE: f64 = 0.6;
    pub const HALLU_CAP_COEF: f64 = 3.6;
    /// Grounded floor: cached-fact answers bottom out near 4pts (Fig 7b).
    pub const GROUND_FLOOR: f64 = 4.2;
    pub const GROUND_BOOST: f64 = 0.8;
    /// Verifier noise: sd = VER_NOISE_BASE + VER_NOISE_CAP * (1 - cap).
    pub const VER_NOISE_BASE: f64 = 0.45;
    pub const VER_NOISE_CAP: f64 = 1.2;
    /// Judge noise per run (§5.3 averages scores over 3-4 runs).
    pub const JUDGE_NOISE_SD: f64 = 0.4;
    /// Weight of measured embedding similarity in the judge score.
    pub const JUDGE_SIM_W: f64 = 0.6;
}

fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Latent traits of a query, assigned by the workload generator.
#[derive(Clone, Debug)]
pub struct QueryTraits {
    /// Stable id used to seed per-query noise.
    pub id: String,
    /// Difficulty in [0,1] (the paper: "most expensive models can be an
    /// overkill for certain, easier, queries").
    pub difficulty: f64,
    /// Factual (vs subjective) — 30% of the WhatsApp workload (§5.3).
    pub factual: bool,
    /// Whether answering well requires conversation context.
    pub requires_context: bool,
}

/// How a response was produced — the factors that shift its latent score.
#[derive(Clone, Copy, Debug)]
pub struct GenCondition {
    /// Fraction of required context present, in [0,1]. Irrelevant when the
    /// query is standalone.
    pub context_sufficiency: f64,
    /// Response was grounded in cached/retrieved factual content.
    pub grounded: bool,
}

impl Default for GenCondition {
    fn default() -> Self {
        GenCondition {
            context_sufficiency: 1.0,
            grounded: false,
        }
    }
}

/// Latent 0-10 quality score for a response produced by a model with
/// `capability` under `cond`.
pub fn latent_score(traits: &QueryTraits, capability: f64, cond: GenCondition) -> f64 {
    let mut rng = Rng::new(seed_of(&[
        "latent",
        &traits.id,
        &format!("{capability:.3}"),
        &format!("{:.2}-{}", cond.context_sufficiency, cond.grounded),
    ]));
    let ctx_penalty = if traits.requires_context {
        calib::CTX_W * (1.0 - cond.context_sufficiency)
    } else {
        0.0
    };
    let logit = calib::S0 + calib::S1 * (capability - traits.difficulty) - ctx_penalty;
    let mut s = 10.0 * sigmoid(logit);

    if traits.factual && !cond.grounded && capability < calib::HALLU_CAP_THRESHOLD {
        // Hallucination lottery: the weaker the model, the likelier the
        // response is confidently wrong.
        let p_hallucinate = (calib::HALLU_CAP_THRESHOLD - capability) * 1.4;
        if rng.chance(p_hallucinate.clamp(0.0, 0.95)) {
            let cap = calib::HALLU_BASE + calib::HALLU_CAP_COEF * capability
                + rng.normal_ms(0.0, 0.5);
            s = s.min(cap.max(0.0));
        }
    }
    if cond.grounded {
        // Cached factual content both lifts and floors the answer.
        s = (s + calib::GROUND_BOOST).max(calib::GROUND_FLOOR + rng.normal_ms(0.0, 0.4));
    }
    (s + rng.normal_ms(0.0, calib::NOISE_SD)).clamp(0.0, 10.0)
}

/// The verifier LLM's 1-10 estimate of a response's quality (§3.3). Its
/// error shrinks with verifier capability.
pub fn verifier_estimate(
    true_score: f64,
    verifier_capability: f64,
    query_id: &str,
) -> f64 {
    let sd = calib::VER_NOISE_BASE + calib::VER_NOISE_CAP * (1.0 - verifier_capability);
    let mut rng = Rng::new(seed_of(&["verifier", query_id, &format!("{verifier_capability:.3}")]));
    (true_score + rng.normal_ms(0.0, sd)).clamp(0.0, 10.0)
}

/// Probability that a small classifier model (SmartContext/SmartCache
/// delegation) makes the *correct* call — rises with capability.
pub fn classifier_accuracy(capability: f64) -> f64 {
    (0.62 + 0.36 * capability).clamp(0.0, 0.99)
}

/// One classifier invocation: returns the model's (possibly wrong) boolean
/// answer given ground truth. `attempt` distinguishes repeated calls (§3.4
/// invokes context-LLM twice to cut false positives).
pub fn classify(ground_truth: bool, capability: f64, query_id: &str, attempt: u32) -> bool {
    let p = classifier_accuracy(capability);
    let mut rng = Rng::new(seed_of(&["classify", query_id, &attempt.to_string()]));
    if rng.chance(p) {
        ground_truth
    } else {
        !ground_truth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traits(id: &str, difficulty: f64) -> QueryTraits {
        QueryTraits {
            id: id.into(),
            difficulty,
            factual: false,
            requires_context: false,
        }
    }

    #[test]
    fn capability_orders_scores() {
        // Averaged over many queries, higher capability => higher score.
        let mut lo = 0.0;
        let mut hi = 0.0;
        for i in 0..200 {
            let t = traits(&format!("q{i}"), 0.3 + 0.4 * (i as f64 / 200.0));
            lo += latent_score(&t, 0.55, GenCondition::default());
            hi += latent_score(&t, 0.88, GenCondition::default());
        }
        assert!(hi / 200.0 > lo / 200.0 + 0.8, "hi={hi} lo={lo}");
    }

    #[test]
    fn deterministic_per_seed() {
        let t = traits("qx", 0.5);
        let a = latent_score(&t, 0.7, GenCondition::default());
        let b = latent_score(&t, 0.7, GenCondition::default());
        assert_eq!(a, b);
    }

    #[test]
    fn missing_context_hurts_dependent_queries_only() {
        let mut dep = traits("qc", 0.4);
        dep.requires_context = true;
        let with = latent_score(&dep, 0.8, GenCondition { context_sufficiency: 1.0, grounded: false });
        let without = latent_score(&dep, 0.8, GenCondition { context_sufficiency: 0.0, grounded: false });
        assert!(with > without + 1.0, "with={with} without={without}");

        let indep = traits("qs", 0.4);
        let a = latent_score(&indep, 0.8, GenCondition { context_sufficiency: 1.0, grounded: false });
        let b = latent_score(&indep, 0.8, GenCondition { context_sufficiency: 0.0, grounded: false });
        // Standalone query: context makes little difference (only noise seed).
        assert!((a - b).abs() < 2.0);
    }

    #[test]
    fn hallucination_and_grounding() {
        // Phi-3-class model on factual queries: ungrounded answers collapse
        // sometimes; grounded answers are floored near 4 (Fig 7b).
        let mut worst_ungrounded: f64 = 10.0;
        let mut worst_grounded: f64 = 10.0;
        for i in 0..300 {
            let t = QueryTraits {
                id: format!("f{i}"),
                difficulty: 0.3 + 0.4 * (i as f64 / 300.0),
                factual: true,
                requires_context: false,
            };
            worst_ungrounded = worst_ungrounded
                .min(latent_score(&t, 0.45, GenCondition::default()));
            worst_grounded = worst_grounded.min(latent_score(
                &t,
                0.45,
                GenCondition { context_sufficiency: 1.0, grounded: true },
            ));
        }
        assert!(worst_ungrounded < 2.5, "worst_ungrounded={worst_ungrounded}");
        assert!(worst_grounded > 3.0, "worst_grounded={worst_grounded}");
        assert!(worst_grounded > worst_ungrounded + 2.0);
    }

    #[test]
    fn verifier_tracks_truth_with_capability() {
        let mut err_weak = 0.0;
        let mut err_strong = 0.0;
        for i in 0..500 {
            let truth = 3.0 + (i % 70) as f64 / 10.0;
            err_weak += (verifier_estimate(truth, 0.5, &format!("v{i}")) - truth).abs();
            err_strong += (verifier_estimate(truth, 0.95, &format!("v{i}")) - truth).abs();
        }
        assert!(err_strong < err_weak, "strong={err_strong} weak={err_weak}");
    }

    #[test]
    fn paper_operating_point_routing_fractions() {
        // §5.3: with t=8, M2 answers >60% of prompts with old models
        // (M1=GPT-3.5, verifier=Opus) and ~25% with new (M1=4o-mini,
        // verifier=4o). Difficulty distribution mirrors the workload.
        let mut rng = Rng::new(99);
        let mut routed_old = 0;
        let mut routed_new = 0;
        let n = 2000;
        for i in 0..n {
            let t = QueryTraits {
                id: format!("rq{i}"),
                difficulty: rng.normal_ms(0.45, 0.18).clamp(0.05, 0.95),
                factual: rng.chance(0.3),
                requires_context: false,
            };
            let s_old = latent_score(&t, 0.55, GenCondition::default());
            if verifier_estimate(s_old, 0.85, &t.id) < 8.0 {
                routed_old += 1;
            }
            let s_new = latent_score(&t, 0.78, GenCondition::default());
            if verifier_estimate(s_new, 0.92, &t.id) < 8.0 {
                routed_new += 1;
            }
        }
        let f_old = routed_old as f64 / n as f64;
        let f_new = routed_new as f64 / n as f64;
        assert!((0.55..=0.80).contains(&f_old), "old routing fraction {f_old}");
        assert!((0.15..=0.40).contains(&f_new), "new routing fraction {f_new}");
        assert!(f_old > f_new + 0.2);
    }

    #[test]
    fn classifier_accuracy_bounds() {
        assert!(classifier_accuracy(0.0) >= 0.6);
        assert!(classifier_accuracy(1.0) <= 0.99);
        // Haiku-class context-LLM lands around 84%.
        let acc = classifier_accuracy(0.60);
        assert!((0.80..=0.90).contains(&acc));
    }
}
