//! Operational resilience layer (ROADMAP item 2): the pieces that keep a
//! long-lived deployment serving through backend outages and traffic
//! bursts without a restart — the paper's deployments ran for months
//! (§5: the WhatsApp bridge 12+ months, the classroom proxy a semester),
//! so operability is part of the reproduction, not an afterthought.
//!
//! * [`CircuitBreaker`] — per-model closed→open→half-open state machines
//!   wrapped around generator calls in the route stage. A sick model
//!   fast-fails with a typed 503 (`"reason":"breaker"` + `Retry-After`)
//!   instead of pinning workers, and per-model state means one sick pool
//!   member doesn't black-hole the rest.
//! * [`RateLimiter`] — per-user token buckets, the admission gate ahead
//!   of the quota check. Sheds with a 429 whose `"reason":"rate"` is
//!   distinct from both the admission 429 and the per-user quota 429.
//! * [`OpsConfig`] — the server-side knobs `POST /admin/config`
//!   hot-reloads. The whole struct swaps through one `Arc`, so a request
//!   that loads the snapshot once observes either the old config or the
//!   new one, never a mix (the validate → swap happens-before edge).

pub mod breaker;
pub mod rate;

pub use breaker::{Admission, BreakerConfig, CircuitBreaker};
pub use rate::RateLimiter;

/// Server-side tunables, hot-reloadable as one unit via
/// `POST /admin/config`. Held in an `RwLock<Arc<OpsConfig>>` on the
/// server state; readers clone the `Arc` once per request and read every
/// field from that snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct OpsConfig {
    /// In-flight dispatched-request watermark (admission control).
    pub shed_watermark: usize,
    /// Token-bucket refill rate per user. `0.0` disables rate limiting
    /// (the default — existing deployments see no behavior change).
    pub rate_per_sec: f64,
    /// Token-bucket capacity: how many requests a user may burst after
    /// an idle period.
    pub rate_burst: f64,
}

impl Default for OpsConfig {
    fn default() -> OpsConfig {
        OpsConfig {
            shed_watermark: 512,
            rate_per_sec: 0.0,
            rate_burst: 16.0,
        }
    }
}
