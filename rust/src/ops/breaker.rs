//! Per-model circuit breaker: closed → open → half-open.
//!
//! The route stage asks [`CircuitBreaker::admit`] before executing a
//! plan and reports the outcome with [`CircuitBreaker::record_success`]
//! / [`CircuitBreaker::record_failure`]. Each model keeps its own state
//! machine, so one sick pool member fast-fails while the rest of the
//! pool keeps serving:
//!
//! ```text
//!              consecutive failures >= threshold
//!   Closed ───────────────────────────────────────▶ Open{until}
//!     ▲                                               │
//!     │ probe succeeds                    now >= until │
//!     │                                               ▼
//!   (reset) ◀─────────────────────────────── HalfOpen{probing}
//!                      probe fails ──▶ back to Open{now + cooldown}
//! ```
//!
//! While `Open`, every admit is denied with the remaining cooldown as a
//! `Retry-After` hint. Once the cooldown lapses the breaker turns
//! half-open and lets exactly **one** probe through at a time; other
//! requests keep shedding until the probe reports back. A successful
//! probe closes the breaker, a failed one re-opens it for a full
//! cooldown.
//!
//! Only infrastructure failures (engine RPC errors/timeouts) count
//! against the breaker — client errors like `BadRequest` never trip it.
//! All methods take `&self`; state lives behind one mutex (the map is
//! touched once per request, nowhere near the hot path's shard locks).

use std::collections::HashMap;
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

/// Breaker tunables, hot-reloadable via `POST /admin/config`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BreakerConfig {
    /// Consecutive infrastructure failures before the breaker opens.
    pub threshold: u32,
    /// How long an open breaker sheds before allowing a half-open probe.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            threshold: 5,
            cooldown: Duration::from_secs(10),
        }
    }
}

/// Verdict of [`CircuitBreaker::admit`] for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    /// Breaker closed: execute normally.
    Allow,
    /// Breaker half-open and this request won the probe slot: execute,
    /// and the recorded outcome decides whether the breaker closes.
    Probe,
    /// Breaker open (or half-open with a probe already in flight):
    /// shed with a 503 carrying this `Retry-After` hint.
    Deny { retry_after: Duration },
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum State {
    Closed,
    Open { until: Instant },
    HalfOpen { probing: bool },
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    state: State,
    consecutive_failures: u32,
    trips: u64,
}

impl Entry {
    fn new() -> Entry {
        Entry {
            state: State::Closed,
            consecutive_failures: 0,
            trips: 0,
        }
    }
}

/// One state-machine line of [`CircuitBreaker::snapshot`].
#[derive(Clone, Debug)]
pub struct BreakerLine {
    pub model: String,
    /// `"closed"`, `"open"`, or `"half-open"`.
    pub state: &'static str,
    pub consecutive_failures: u32,
    pub trips: u64,
    /// Remaining cooldown when open, else 0.
    pub retry_after_secs: u64,
}

struct Inner {
    config: BreakerConfig,
    models: HashMap<String, Entry>,
}

/// Per-model circuit breaker; see the module docs for the state machine.
pub struct CircuitBreaker {
    inner: Mutex<Inner>,
}

/// When half-open with a probe already dispatched, concurrent requests
/// are denied with this short hint rather than the full cooldown — the
/// probe's verdict is at most one request away.
const PROBE_RETRY: Duration = Duration::from_secs(1);

impl CircuitBreaker {
    pub fn new(config: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            inner: Mutex::new(Inner {
                config,
                models: HashMap::new(),
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A panic while holding this mutex leaves consistent state (all
        // mutations are single assignments), so poisoning is recoverable.
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn config(&self) -> BreakerConfig {
        self.lock().config
    }

    /// Swap tunables atomically. Existing open/half-open state is kept;
    /// the new threshold/cooldown apply from the next transition.
    pub fn set_config(&self, config: BreakerConfig) {
        self.lock().config = config;
    }

    /// Gate one request against `model`'s breaker.
    pub fn admit(&self, model: &str) -> Admission {
        self.admit_at(model, Instant::now())
    }

    /// `admit` with an explicit clock, for deterministic tests.
    pub fn admit_at(&self, model: &str, now: Instant) -> Admission {
        let mut g = self.lock();
        let entry = g.models.entry(model.to_string()).or_insert_with(Entry::new);
        match entry.state {
            State::Closed => Admission::Allow,
            State::Open { until } => {
                if now < until {
                    Admission::Deny {
                        retry_after: until - now,
                    }
                } else {
                    // Cooldown lapsed: this request becomes the probe.
                    entry.state = State::HalfOpen { probing: true };
                    Admission::Probe
                }
            }
            State::HalfOpen { probing } => {
                if probing {
                    Admission::Deny {
                        retry_after: PROBE_RETRY,
                    }
                } else {
                    entry.state = State::HalfOpen { probing: true };
                    Admission::Probe
                }
            }
        }
    }

    /// Report a successful execution. Returns `true` if this success
    /// closed a half-open breaker (a recovery, worth a counter).
    pub fn record_success(&self, model: &str) -> bool {
        let mut g = self.lock();
        let entry = g.models.entry(model.to_string()).or_insert_with(Entry::new);
        match entry.state {
            State::HalfOpen { .. } => {
                entry.state = State::Closed;
                entry.consecutive_failures = 0;
                true
            }
            State::Closed => {
                entry.consecutive_failures = 0;
                false
            }
            // A success racing an already-open breaker (request admitted
            // before the trip) doesn't close it early.
            State::Open { .. } => false,
        }
    }

    /// Report an infrastructure failure. Returns `true` if this failure
    /// tripped the breaker open (closed→open or a failed probe).
    pub fn record_failure(&self, model: &str) -> bool {
        self.record_failure_at(model, Instant::now())
    }

    /// `record_failure` with an explicit clock, for deterministic tests.
    pub fn record_failure_at(&self, model: &str, now: Instant) -> bool {
        let mut g = self.lock();
        let cooldown = g.config.cooldown;
        let threshold = g.config.threshold.max(1);
        let entry = g.models.entry(model.to_string()).or_insert_with(Entry::new);
        match entry.state {
            State::Closed => {
                entry.consecutive_failures += 1;
                if entry.consecutive_failures >= threshold {
                    entry.state = State::Open {
                        until: now + cooldown,
                    };
                    entry.trips += 1;
                    true
                } else {
                    false
                }
            }
            State::HalfOpen { .. } => {
                entry.state = State::Open {
                    until: now + cooldown,
                };
                entry.trips += 1;
                true
            }
            // Late failures from requests admitted pre-trip don't extend
            // the cooldown.
            State::Open { .. } => false,
        }
    }

    /// Point-in-time view of every model's breaker, for `/admin/breaker`.
    pub fn snapshot(&self) -> Vec<BreakerLine> {
        self.snapshot_at(Instant::now())
    }

    pub fn snapshot_at(&self, now: Instant) -> Vec<BreakerLine> {
        let g = self.lock();
        let mut lines: Vec<BreakerLine> = g
            .models
            .iter()
            .map(|(model, e)| {
                let (state, retry) = match e.state {
                    State::Closed => ("closed", 0),
                    State::Open { until } => {
                        ("open", until.saturating_duration_since(now).as_secs())
                    }
                    State::HalfOpen { .. } => ("half-open", 0),
                };
                BreakerLine {
                    model: model.clone(),
                    state,
                    consecutive_failures: e.consecutive_failures,
                    trips: e.trips,
                    retry_after_secs: retry,
                }
            })
            .collect();
        lines.sort_by(|a, b| a.model.cmp(&b.model));
        lines
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(threshold: u32, cooldown_ms: u64) -> BreakerConfig {
        BreakerConfig {
            threshold,
            cooldown: Duration::from_millis(cooldown_ms),
        }
    }

    #[test]
    fn trips_after_consecutive_failures_only() {
        let b = CircuitBreaker::new(cfg(3, 1000));
        let t0 = Instant::now();
        assert!(!b.record_failure_at("m", t0));
        assert!(!b.record_failure_at("m", t0));
        // A success in between resets the consecutive count.
        assert!(!b.record_success("m"));
        assert!(!b.record_failure_at("m", t0));
        assert!(!b.record_failure_at("m", t0));
        assert_eq!(b.admit_at("m", t0), Admission::Allow);
        assert!(b.record_failure_at("m", t0));
        match b.admit_at("m", t0) {
            Admission::Deny { retry_after } => {
                assert!(retry_after <= Duration::from_millis(1000))
            }
            other => panic!("expected Deny while open, got {other:?}"),
        }
    }

    #[test]
    fn cooldown_then_single_probe_then_recovery() {
        let b = CircuitBreaker::new(cfg(1, 1000));
        let t0 = Instant::now();
        assert!(b.record_failure_at("m", t0));
        // Still open just before the cooldown lapses.
        assert!(matches!(
            b.admit_at("m", t0 + Duration::from_millis(999)),
            Admission::Deny { .. }
        ));
        // Cooldown lapsed: first request is the probe, concurrent ones shed.
        let t1 = t0 + Duration::from_millis(1001);
        assert_eq!(b.admit_at("m", t1), Admission::Probe);
        assert!(matches!(b.admit_at("m", t1), Admission::Deny { .. }));
        // Probe success closes the breaker and is reported as a recovery.
        assert!(b.record_success("m"));
        assert_eq!(b.admit_at("m", t1), Admission::Allow);
        assert!(!b.record_success("m"));
    }

    #[test]
    fn failed_probe_reopens_for_full_cooldown() {
        let b = CircuitBreaker::new(cfg(1, 1000));
        let t0 = Instant::now();
        b.record_failure_at("m", t0);
        let t1 = t0 + Duration::from_millis(1500);
        assert_eq!(b.admit_at("m", t1), Admission::Probe);
        assert!(b.record_failure_at("m", t1));
        // Re-opened from the probe's failure time, not the original trip.
        assert!(matches!(
            b.admit_at("m", t1 + Duration::from_millis(999)),
            Admission::Deny { .. }
        ));
        assert_eq!(
            b.admit_at("m", t1 + Duration::from_millis(1001)),
            Admission::Probe
        );
    }

    #[test]
    fn per_model_isolation() {
        let b = CircuitBreaker::new(cfg(1, 1000));
        let t0 = Instant::now();
        assert!(b.record_failure_at("sick", t0));
        assert!(matches!(b.admit_at("sick", t0), Admission::Deny { .. }));
        assert_eq!(b.admit_at("healthy", t0), Admission::Allow);
        let snap = b.snapshot_at(t0);
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].model, "healthy");
        assert_eq!(snap[0].state, "closed");
        assert_eq!(snap[1].model, "sick");
        assert_eq!(snap[1].state, "open");
        assert_eq!(snap[1].trips, 1);
    }

    #[test]
    fn config_swap_applies_to_next_transition() {
        let b = CircuitBreaker::new(cfg(5, 1000));
        let t0 = Instant::now();
        for _ in 0..4 {
            assert!(!b.record_failure_at("m", t0));
        }
        b.set_config(cfg(2, 1000));
        // Already at 4 consecutive >= new threshold 2: next failure trips.
        assert!(b.record_failure_at("m", t0));
    }
}
