//! Per-user token-bucket rate limiting — the admission gate ahead of
//! the quota check.
//!
//! The quota gate (AccountStage's `reserve_quota_slot`) bounds a user's
//! *daily budget*; this bucket bounds their *instantaneous rate*, which
//! is what actually protects the server from the bursty, heavy-tailed
//! arrival patterns LLM traffic exhibits ("Introducing LLMs as the Next
//! Challenging Internet Traffic Source", PAPERS.md). Each user's bucket
//! holds up to `burst` tokens and refills at `rate_per_sec`; a request
//! spends one token or is shed with a 429 whose `"reason":"rate"` is
//! distinct from the admission and quota 429s.
//!
//! rate/burst are passed per call (not stored here) so `POST
//! /admin/config` hot-reloads take effect on the next request without
//! touching bucket state: a user's accumulated tokens survive a config
//! swap, clamped to the new burst on the next refill.

use std::collections::HashMap;
use std::sync::{Mutex, PoisonError};
use std::time::Instant;

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Cap on distinct users tracked. Above this, buckets that have fully
/// refilled (idle long enough to be indistinguishable from fresh) are
/// pruned; if none can be pruned the new user is admitted untracked for
/// this one request rather than letting the map grow without bound.
const MAX_TRACKED_USERS: usize = 65_536;

/// Per-user token buckets; see the module docs.
pub struct RateLimiter {
    buckets: Mutex<HashMap<String, Bucket>>,
}

impl Default for RateLimiter {
    fn default() -> RateLimiter {
        RateLimiter::new()
    }
}

impl RateLimiter {
    pub fn new() -> RateLimiter {
        RateLimiter {
            buckets: Mutex::new(HashMap::new()),
        }
    }

    /// Try to spend one token from `user`'s bucket. `Ok(())` admits the
    /// request; `Err(secs)` sheds it with a `Retry-After` hint of when
    /// one token will have refilled. `rate_per_sec <= 0` disables the
    /// limiter entirely (every call admits, no state is kept).
    pub fn try_acquire(&self, rate_per_sec: f64, burst: f64, user: &str) -> Result<(), u64> {
        self.try_acquire_at(rate_per_sec, burst, user, Instant::now())
    }

    /// `try_acquire` with an explicit clock, for deterministic tests.
    pub fn try_acquire_at(
        &self,
        rate_per_sec: f64,
        burst: f64,
        user: &str,
        now: Instant,
    ) -> Result<(), u64> {
        if rate_per_sec <= 0.0 {
            return Ok(());
        }
        let burst = burst.max(1.0);
        let mut g = self.buckets.lock().unwrap_or_else(PoisonError::into_inner);
        if !g.contains_key(user) && g.len() >= MAX_TRACKED_USERS {
            // Full buckets carry no history worth keeping — refilled to
            // the brim, they behave exactly like a fresh entry.
            g.retain(|_, b| {
                let dt = now.saturating_duration_since(b.last).as_secs_f64();
                (b.tokens + dt * rate_per_sec) < burst
            });
            if g.len() >= MAX_TRACKED_USERS {
                return Ok(());
            }
        }
        let bucket = g.entry(user.to_string()).or_insert(Bucket {
            tokens: burst,
            last: now,
        });
        let dt = now.saturating_duration_since(bucket.last).as_secs_f64();
        bucket.tokens = (bucket.tokens + dt * rate_per_sec).min(burst);
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            Ok(())
        } else {
            let secs = ((1.0 - bucket.tokens) / rate_per_sec).ceil();
            Err((secs as u64).max(1))
        }
    }

    /// Number of users currently tracked (admin/test visibility).
    pub fn tracked_users(&self) -> usize {
        self.buckets
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use std::time::Duration;

    #[test]
    fn burst_then_shed_then_refill() {
        let rl = RateLimiter::new();
        let t0 = Instant::now();
        // Fresh bucket holds `burst` tokens: exactly 3 succeed at t0.
        for _ in 0..3 {
            assert!(rl.try_acquire_at(2.0, 3.0, "u", t0).is_ok());
        }
        let retry = rl.try_acquire_at(2.0, 3.0, "u", t0).unwrap_err();
        assert_eq!(retry, 1); // 1 token / 2 per sec = 0.5s, ceil+floor → 1
        // 500ms refills one token at 2/sec.
        let t1 = t0 + Duration::from_millis(500);
        assert!(rl.try_acquire_at(2.0, 3.0, "u", t1).is_ok());
        assert!(rl.try_acquire_at(2.0, 3.0, "u", t1).is_err());
    }

    #[test]
    fn users_do_not_share_buckets() {
        let rl = RateLimiter::new();
        let t0 = Instant::now();
        assert!(rl.try_acquire_at(1.0, 1.0, "a", t0).is_ok());
        assert!(rl.try_acquire_at(1.0, 1.0, "a", t0).is_err());
        assert!(rl.try_acquire_at(1.0, 1.0, "b", t0).is_ok());
    }

    #[test]
    fn zero_rate_disables_limiting() {
        let rl = RateLimiter::new();
        let t0 = Instant::now();
        for _ in 0..100 {
            assert!(rl.try_acquire_at(0.0, 1.0, "u", t0).is_ok());
        }
        assert_eq!(rl.tracked_users(), 0);
    }

    /// Property: over any arrival schedule, the number of admitted
    /// requests never exceeds burst + rate * elapsed + 1 (the +1 covers
    /// the fractional token in flight), and a bucket drained at a single
    /// instant admits at most `burst`.
    #[test]
    fn prop_admissions_bounded_by_refill() {
        prop::forall(
            0x5eed_4a7e,
            64,
            |r| {
                let rate = 1.0 + (r.below(40) as f64) / 4.0; // 1.0..=10.75
                let burst = 1.0 + r.below(12) as f64; // 1..=12
                // Arrival schedule: 1..=120 requests at millisecond offsets.
                let n = 1 + r.below(120);
                let mut at_ms = Vec::with_capacity(n);
                let mut t = 0u64;
                for _ in 0..n {
                    t += r.below(400) as u64; // 0..399ms gaps
                    at_ms.push(t);
                }
                (rate, burst, at_ms)
            },
            |(rate, burst, at_ms)| {
                let rl = RateLimiter::new();
                let t0 = Instant::now();
                let mut granted = 0u64;
                for &ms in at_ms {
                    if rl
                        .try_acquire_at(*rate, *burst, "u", t0 + Duration::from_millis(ms))
                        .is_ok()
                    {
                        granted += 1;
                    }
                }
                let elapsed = *at_ms.last().unwrap() as f64 / 1000.0;
                granted as f64 <= burst + rate * elapsed + 1.0
            },
        );
    }

    #[test]
    fn idle_bucket_refills_to_burst_exactly() {
        let rl = RateLimiter::new();
        let t0 = Instant::now();
        // Drain the bucket.
        for _ in 0..4 {
            let _ = rl.try_acquire_at(2.0, 4.0, "u", t0);
        }
        assert!(rl.try_acquire_at(2.0, 4.0, "u", t0).is_err());
        // A long idle refills to the cap (not beyond): exactly 4 admits.
        let t1 = t0 + Duration::from_secs(3600);
        for _ in 0..4 {
            assert!(rl.try_acquire_at(2.0, 4.0, "u", t1).is_ok());
        }
        assert!(rl.try_acquire_at(2.0, 4.0, "u", t1).is_err());
    }
}
