//! Adaptive vector-index tier — what the semantic cache actually holds.
//!
//! Small corpora are a solved problem: a blocked flat scan over a few
//! thousand rows beats any ANN structure and is *exact*. A months-old
//! deployment cache is not small — §3.5's cost absorption only pays off if
//! a 10⁵–10⁶-row corpus still answers GETs on the hot path. The adaptive
//! index serves both regimes behind one [`VectorIndex`]:
//!
//! * **Flat tier** (below [`AdaptiveConfig::migrate_threshold`] rows):
//!   delegates verbatim to [`FlatIndex`] — results are bit-exact with the
//!   pre-adaptive cache by construction.
//! * **IVF tier** (at/above the threshold): a k-means-trained
//!   [`IvfIndex`] probing [`AdaptiveConfig::nprobe`] cells, widened by the
//!   cache's over-fetch GET via [`AdaptiveIndex::search_effort`] so recall
//!   escalates (up to an exhaustive all-cells probe) before a miss is
//!   declared.
//! * **Quantized IVF tier** (at/above
//!   [`AdaptiveConfig::quantize_threshold`] rows): the same coarse
//!   structure over i8-quantized rows ([`QuantIvfIndex`]) — `dim + 4`
//!   bytes/row instead of `4·dim`, an i8 coarse scan with f32 rescore, and
//!   recall@4 ≥ 0.95 gated by the same clustered-corpus property test as
//!   the f32 tier. Promotion rides the identical plan/train/install
//!   machinery, so the requantization never blocks the read path either.
//!
//! ## Retraining off the read path
//!
//! Migration and retraining are **not** done inside `insert` — k-means
//! over 10⁵ rows takes seconds and the cache's index lock must never be
//! held that long. Instead:
//!
//! 1. a maintenance caller (the cache's `maybe_rebuild_index`, polled by
//!    the server janitor) takes [`AdaptiveIndex::rebuild_plan`] under the
//!    read lock — a cheap row export + the current mutation epoch;
//! 2. [`RebuildPlan::train`] runs k-means with **no lock held** (training
//!    set sampled down to [`AdaptiveConfig::train_sample`] rows);
//! 3. [`AdaptiveIndex::install`] swaps the trained tier in under a brief
//!    write lock. Mutations that landed between plan and install are
//!    **reconciled** (inserted into / removed from the trained tier) so
//!    the swap never loses or resurrects a row — the install is atomic
//!    *and* content-preserving under concurrent churn.
//!
//! Retrains are re-triggered by churn: once inserts+removals since the
//! last train exceed [`AdaptiveConfig::retrain_fraction`] of the trained
//! corpus, the centroids are considered drifted.
//!
//! ## Snapshot format
//!
//! `save`/`load` round-trip the trained state so a cold restore **never
//! re-trains**: the flat tier writes the LBV2 bulk-row format unchanged,
//! the IVF tier writes LBV3 — LBV2's geometry plus a trained section.
//! `load` accepts both (a pre-adaptive LBV2 snapshot boots as the flat
//! tier and migrates through the normal maintenance path). LBV3 layout:
//!
//! ```text
//! "LBV3"                          4-byte magic
//! [dim    u32][metric u8]         geometry (as LBV2)
//! [count  u64]
//! [nlist  u32][nprobe u32]        trained policy — a restored index keeps
//!                                 the nprobe it was trained under
//! [crc    u64]                    FNV-1a over the payload below
//! [ids         count×u64]         payload: rows …
//! [rows        count×dim×f32]     … pre-normalized, row-major
//! [assignments count×u32]         cell per row
//! [centroids   nlist×dim×f32]     trained coarse quantizer
//! ```
//!
//! The checksum puts LBV3 on par with the persist layer's other durable
//! artifacts: an in-range payload bit-flip — e.g. an assignment silently
//! pointing at the wrong cell — must fail the load, not quietly lose
//! recall.
//!
//! The quantized tier writes **LBV4**, designed so a cold boot maps the
//! code region instead of reading it — `load` returns before the corpus
//! is resident and first queries fault pages in on demand (see the layout
//! diagram at `LBV4_HEADER` and the byte-level walkthrough in
//! `persist::snapshot`). `load` accepts all three generations; LBV4 is
//! only *written* once the corpus has actually crossed the quantize
//! threshold, so pre-quantization deployments keep producing snapshots
//! their older binaries can read back.

use std::sync::atomic::{AtomicU64, Ordering};
#[cfg(unix)]
use std::sync::Arc;

use anyhow::{bail, Result};

use super::flat::FlatIndex;
use super::ivf::{kmeans_centroids, nearest_centroid, IvfIndex};
use super::quant::{codes_as_bytes, CodesSource, QuantIvfIndex};
use super::{Hit, Metric, VectorIndex};
#[cfg(unix)]
use crate::util::mmap::MmapRegion;
use crate::util::rng::Rng;

/// Process-unique identity per [`AdaptiveIndex`] value. A [`RebuildPlan`]
/// remembers the instance it was exported from so [`AdaptiveIndex::install`]
/// can refuse a trained tier whose source index has since been *replaced*
/// (e.g. the cache's `clear()` swapping in a fresh index) — epoch counters
/// alone cannot tell "mutated" from "different index that restarted at 0".
static NEXT_INSTANCE: AtomicU64 = AtomicU64::new(1);

fn fresh_instance() -> u64 {
    NEXT_INSTANCE.fetch_add(1, Ordering::Relaxed)
}

/// LBV3 snapshot magic: LBV2 geometry + trained IVF section.
const LBV3_MAGIC: &[u8; 4] = b"LBV3";
/// magic(4) + dim(u32) + metric(u8) + count(u64) + nlist(u32) + nprobe(u32)
/// + fnv1a-crc(u64) over the payload (ids, rows, assignments, centroids).
/// The checksum puts LBV3 on par with the persist layer's other durable
/// artifacts (WAL records, kv.jsonl): an in-range payload bit-flip — e.g.
/// an assignment silently pointing at the wrong cell — must fail the load,
/// not quietly lose recall.
const LBV3_HEADER: usize = 4 + 4 + 1 + 8 + 4 + 4 + 8;

/// LBV4 snapshot magic: LBV3's trained section, rows i8-quantized, code
/// region mmap-aligned for lazy cold boot.
const LBV4_MAGIC: &[u8; 4] = b"LBV4";
/// LBV4 layout:
///
/// ```text
/// "LBV4"                          4-byte magic
/// [dim       u32][metric u8]      geometry (as LBV2/LBV3)
/// [count     u64]
/// [nlist     u32][nprobe u32]     trained policy (as LBV3)
/// [codes_off u64]                 file offset of the code region,
///                                 4096-aligned: header+metadata faults
///                                 stay off the code pages on 4k systems
/// [meta_crc  u64]                 FNV-1a over the metadata payload
/// [codes_crc u64]                 FNV-1a over the code region
/// [ids         count×u64]         metadata payload: cell-grouped rows …
/// [assignments count×u32]         … non-decreasing cell per row
/// [scales      count×f32]         per-row dequantization scale
/// [centroids   nlist×dim×f32]     trained coarse quantizer
/// [zero-pad    to codes_off]
/// [codes       count×dim×i8]      row-major, cell-contiguous
/// ```
///
/// The split checksum is what makes the lazy boot sound: `meta_crc` is
/// verified eagerly on every load (metadata is a few pages), while
/// `codes_crc` covers the region the mapped path deliberately does *not*
/// read — it is verified on the eager (non-unix / in-memory) path, and
/// kept in the header so any reader *can* audit a suspect file.
const LBV4_HEADER: usize = 4 + 4 + 1 + 8 + 4 + 4 + 8 + 8 + 8;

/// Align the code region to 4096 bytes. The map itself is whole-file from
/// offset 0 (no mmap alignment constraint), but keeping codes on their own
/// 4k pages means the eager metadata parse on load faults no code page on
/// the common 4k-page systems — the laziness the format exists for.
fn align_up_4k(n: usize) -> usize {
    (n + 4095) & !4095
}

/// Tier/retrain policy knobs (defaults are the cache's production shape).
#[derive(Clone, Debug)]
pub struct AdaptiveConfig {
    /// Row count at/above which the flat tier migrates to IVF. Below it a
    /// flat scan is both faster and exact.
    pub migrate_threshold: usize,
    /// Row count at/above which a (re)train builds the i8-quantized IVF
    /// tier instead of the f32 one — `dim + 4` bytes/row instead of
    /// `4·dim`, coarse-i8 scan + f32 rescore. The default keeps corpora
    /// under ~a quarter-million rows on exact f32 arithmetic; above that,
    /// memory-bandwidth wins dominate the quantization error (recall@4
    /// stays ≥ 0.95 on clustered corpora — gated by test).
    pub quantize_threshold: usize,
    /// Cells probed per query at effort 0; each over-fetch widening step
    /// doubles it (capped at an exhaustive all-cells probe). This is the
    /// value a (re)train stamps onto the IVF tier — the live tier's own
    /// (LBV3-persisted) nprobe is what queries actually use, so a restored
    /// index keeps the policy it was trained under.
    pub nprobe: usize,
    /// Lloyd iterations per (re)train.
    pub kmeans_iters: usize,
    /// k-means training-set cap: larger corpora are sampled down so a
    /// retrain stays O(train_sample · nlist) per iteration.
    pub train_sample: usize,
    /// Retrain once (inserts + removals since the last train) exceeds this
    /// fraction of the trained corpus — the drift trigger.
    pub retrain_fraction: f64,
    /// Deterministic k-means seed (mixed with the mutation epoch so
    /// successive retrains explore different initializations).
    pub seed: u64,
}

impl Default for AdaptiveConfig {
    fn default() -> AdaptiveConfig {
        AdaptiveConfig {
            migrate_threshold: 8192,
            quantize_threshold: 262_144,
            nprobe: 8,
            kmeans_iters: 4,
            train_sample: 16384,
            retrain_fraction: 0.5,
            seed: 0x1DB5,
        }
    }
}

impl AdaptiveConfig {
    /// Coarse-cell count for an `n`-row corpus: ~sqrt(n), clamped.
    fn nlist_for(&self, n: usize) -> usize {
        ((n as f64).sqrt().round() as usize).clamp(8, 1024).min(n.max(1))
    }
}

#[derive(Debug)]
enum Tier {
    Flat(FlatIndex),
    Ivf(IvfIndex),
    IvfQ(QuantIvfIndex),
}

/// Diagnostics surfaced through `SemanticCache::index_stats` (tests, the
/// persistence suite's "restored without retraining" assertion, ops).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IndexStats {
    /// `"flat"`, `"ivf"`, or `"ivf_i8"`.
    pub tier: &'static str,
    pub rows: usize,
    /// Whether the IVF tier holds trained centroids (always false on flat).
    pub trained: bool,
    /// Coarse cells (0 on flat).
    pub nlist: usize,
    /// Logical bytes of the scanned vector region: `rows·dim·4` on the f32
    /// tiers, `rows·(dim+4)` on the quantized tier — what the ≥ 3.5x
    /// memory-cut acceptance gate measures.
    pub vector_bytes: usize,
}

/// Everything a trainer needs, exported under the read lock: row snapshot
/// plus the (instance, mutation-epoch) pair it corresponds to.
pub struct RebuildPlan {
    cfg: AdaptiveConfig,
    dim: usize,
    metric: Metric,
    ids: Vec<u64>,
    /// Row-major, already in stored form (cosine rows pre-normalized).
    rows: Vec<f32>,
    instance: u64,
    epoch: u64,
}

/// Which index a (re)train produced — f32 IVF below the quantize
/// threshold, i8 IVF at/above it. Both expose the same reconcile surface
/// (contains / insert_stored / remove / for_each_row), which is all
/// [`AdaptiveIndex::install`] needs.
enum TrainedKind {
    Ivf(IvfIndex),
    IvfQ(QuantIvfIndex),
}

impl TrainedKind {
    fn len(&self) -> usize {
        match self {
            TrainedKind::Ivf(i) => i.len(),
            TrainedKind::IvfQ(q) => q.len(),
        }
    }

    fn contains(&self, id: u64) -> bool {
        match self {
            TrainedKind::Ivf(i) => i.contains(id),
            TrainedKind::IvfQ(q) => q.contains(id),
        }
    }

    fn insert_stored(&mut self, id: u64, row: &[f32]) -> Result<()> {
        match self {
            TrainedKind::Ivf(i) => i.insert_stored(id, row),
            TrainedKind::IvfQ(q) => q.insert_stored(id, row),
        }
    }

    fn remove(&mut self, id: u64) -> bool {
        match self {
            TrainedKind::Ivf(i) => i.remove(id),
            TrainedKind::IvfQ(q) => q.remove(id),
        }
    }

    fn for_each_row(&self, f: impl FnMut(u64, &[f32])) {
        match self {
            TrainedKind::Ivf(i) => i.for_each_row(f),
            TrainedKind::IvfQ(q) => q.for_each_row(f),
        }
    }
}

/// A trained IVF tier (f32 or quantized) ready to
/// [`AdaptiveIndex::install`].
pub struct TrainedTier {
    kind: TrainedKind,
    instance: u64,
    epoch: u64,
}

impl RebuildPlan {
    /// Run k-means and assign every exported row — pure CPU, call with no
    /// lock held. Deterministic for a given (config seed, epoch).
    pub fn train(self) -> TrainedTier {
        let n = self.ids.len();
        let nlist = self.cfg.nlist_for(n);
        let mut rng = Rng::new(self.cfg.seed ^ self.epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // Sample the training set; assignments below still cover all rows.
        let train_rows: Vec<f32> = if n > self.cfg.train_sample {
            let picks = rng.sample_indices(n, self.cfg.train_sample);
            picks
                .iter()
                .flat_map(|&i| self.rows[i * self.dim..(i + 1) * self.dim].iter().copied())
                .collect()
        } else {
            self.rows.clone()
        };
        let centroids = kmeans_centroids(
            &mut rng,
            self.metric,
            &train_rows,
            self.dim,
            nlist,
            self.cfg.kmeans_iters.max(1),
        );
        let assignments: Vec<u32> = (0..n)
            .map(|i| {
                nearest_centroid(
                    self.metric,
                    &centroids,
                    self.dim,
                    &self.rows[i * self.dim..(i + 1) * self.dim],
                ) as u32
            })
            .collect();
        // At/above the quantize threshold the trained tier stores i8 codes
        // instead of f32 rows — same centroids, same assignments.
        let kind = if n >= self.cfg.quantize_threshold {
            TrainedKind::IvfQ(
                QuantIvfIndex::from_trained_parts(
                    self.dim,
                    self.metric,
                    self.cfg.nprobe,
                    centroids,
                    self.ids,
                    &self.rows,
                    &assignments,
                )
                .expect("self-built trained parts are consistent"),
            )
        } else {
            TrainedKind::Ivf(
                IvfIndex::from_trained_parts(
                    self.dim,
                    self.metric,
                    self.cfg.nprobe,
                    centroids,
                    self.ids,
                    self.rows,
                    &assignments,
                )
                .expect("self-built trained parts are consistent"),
            )
        };
        TrainedTier {
            kind,
            instance: self.instance,
            epoch: self.epoch,
        }
    }
}

#[derive(Debug)]
pub struct AdaptiveIndex {
    cfg: AdaptiveConfig,
    tier: Tier,
    /// Process-unique identity (see [`NEXT_INSTANCE`]): lets `install`
    /// reject a trained tier whose source index was replaced wholesale.
    instance: u64,
    /// Bumped on every content mutation; a [`RebuildPlan`] remembers the
    /// epoch it exported so [`AdaptiveIndex::install`] knows whether it
    /// must reconcile.
    epoch: u64,
    /// Rows present when the IVF tier was last trained (0 on flat).
    trained_rows: usize,
    /// Inserts + removals since the last train — the drift counter.
    churn_since_train: usize,
}

impl AdaptiveIndex {
    pub fn new(dim: usize, metric: Metric, cfg: AdaptiveConfig) -> AdaptiveIndex {
        AdaptiveIndex::from_flat(FlatIndex::new(dim, metric), cfg)
    }

    /// Adopt an existing flat index as the flat tier (bulk restore of LBV2
    /// snapshots; also the `restore_bulk` entry point).
    pub fn from_flat(flat: FlatIndex, cfg: AdaptiveConfig) -> AdaptiveIndex {
        AdaptiveIndex {
            cfg,
            tier: Tier::Flat(flat),
            instance: fresh_instance(),
            epoch: 0,
            trained_rows: 0,
            churn_since_train: 0,
        }
    }

    pub fn config(&self) -> &AdaptiveConfig {
        &self.cfg
    }

    pub fn metric(&self) -> Metric {
        match &self.tier {
            Tier::Flat(f) => f.metric(),
            Tier::Ivf(i) => i.metric(),
            Tier::IvfQ(q) => q.metric(),
        }
    }

    /// Whether `id` has a row (O(1) on every tier).
    pub fn contains(&self, id: u64) -> bool {
        match &self.tier {
            Tier::Flat(f) => f.contains(id),
            Tier::Ivf(i) => i.contains(id),
            Tier::IvfQ(q) => q.contains(id),
        }
    }

    /// Cells of the quantized tier still backed by lazy mmap views of an
    /// LBV4 snapshot (0 on other tiers, or once churn has materialized
    /// everything) — what the boot-laziness tests observe.
    pub fn lazy_cells(&self) -> usize {
        match &self.tier {
            Tier::IvfQ(q) => q.mapped_cells(),
            _ => 0,
        }
    }

    pub fn stats(&self) -> IndexStats {
        match &self.tier {
            Tier::Flat(f) => IndexStats {
                tier: "flat",
                rows: f.len(),
                trained: false,
                nlist: 0,
                vector_bytes: f.len() * f.dim() * 4,
            },
            Tier::Ivf(i) => IndexStats {
                tier: "ivf",
                rows: i.len(),
                trained: i.is_trained(),
                nlist: i.nlist(),
                vector_bytes: i.len() * i.dim() * 4,
            },
            Tier::IvfQ(q) => IndexStats {
                tier: "ivf_i8",
                rows: q.len(),
                trained: true,
                nlist: q.nlist(),
                vector_bytes: q.vector_bytes(),
            },
        }
    }

    /// Top-k at an escalating effort level — the cache's over-fetch GET
    /// passes its widening attempt number. Effort `e` probes
    /// `nprobe * 2^e` cells. The second return value is `true` when the
    /// scan was exhaustive (flat, or every cell probed): only then can the
    /// caller conclude that nothing above `min_score` was missed.
    pub fn search_effort(
        &self,
        query: &[f32],
        k: usize,
        min_score: f32,
        effort: u32,
    ) -> (Vec<Hit>, bool) {
        match &self.tier {
            Tier::Flat(f) => (f.search(query, k, min_score), true),
            Tier::Ivf(i) => {
                if !i.is_trained() {
                    // Untrained IVF scans pending exactly (not reachable
                    // through the cache, which only installs trained tiers).
                    return (i.search(query, k, min_score), true);
                }
                // Base probes come from the live tier (stamped at train
                // time, LBV3-persisted) so a restored index keeps the
                // policy it was trained under.
                let probes = i
                    .nprobe
                    .max(1)
                    .saturating_mul(1usize << effort.min(20))
                    .min(i.nlist());
                (
                    i.search_probes(query, k, min_score, probes),
                    probes >= i.nlist(),
                )
            }
            Tier::IvfQ(q) => {
                // Same widening policy as the f32 IVF tier. "Exhaustive"
                // here means every cell was probed — scores are still
                // rescored-exact, so a full probe is as good as flat for
                // the caller's miss decision.
                let probes = q
                    .nprobe
                    .max(1)
                    .saturating_mul(1usize << effort.min(20))
                    .min(q.nlist());
                (
                    q.search_probes(query, k, min_score, probes),
                    probes >= q.nlist(),
                )
            }
        }
    }

    /// Does the index want a (re)train? Flat: the corpus outgrew the
    /// migration threshold. IVF: churn since the last train exceeds the
    /// drift fraction, or the corpus outgrew the quantize threshold (the
    /// next train then produces the i8 tier). Quantized IVF: churn drift
    /// only — there is no further tier to promote to.
    pub fn needs_rebuild(&self) -> bool {
        let drifted = self.churn_since_train as f64
            >= self.cfg.retrain_fraction * self.trained_rows.max(1) as f64;
        match &self.tier {
            Tier::Flat(f) => !f.is_empty() && f.len() >= self.cfg.migrate_threshold,
            Tier::Ivf(i) => drifted || i.len() >= self.cfg.quantize_threshold,
            Tier::IvfQ(_) => drifted,
        }
    }

    /// Export a training plan (row snapshot + epoch) if a rebuild is due.
    /// Cheap enough for a read-locked critical section: one bulk copy of
    /// ids and rows.
    pub fn rebuild_plan(&self) -> Option<RebuildPlan> {
        if !self.needs_rebuild() || self.len() == 0 {
            return None;
        }
        let (ids, rows) = match &self.tier {
            Tier::Flat(f) => (f.ids().to_vec(), f.rows().to_vec()),
            Tier::Ivf(i) => {
                let (ids, rows, _) = i.export_parts();
                (ids, rows)
            }
            Tier::IvfQ(q) => {
                // Export dequantized rows: re-quantization is idempotent
                // (see `quant`), so a retrain over these rows reproduces
                // the codes rather than compounding quantization error.
                let mut ids = Vec::with_capacity(q.len());
                let mut rows = Vec::with_capacity(q.len() * q.dim());
                q.for_each_row(|id, row| {
                    ids.push(id);
                    rows.extend_from_slice(row);
                });
                (ids, rows)
            }
        };
        Some(RebuildPlan {
            cfg: self.cfg.clone(),
            dim: self.dim(),
            metric: self.metric(),
            ids,
            rows,
            instance: self.instance,
            epoch: self.epoch,
        })
    }

    /// Swap a trained tier in (write-locked critical section). If
    /// mutations landed since the plan's epoch, the delta is reconciled
    /// into the trained tier first — rows inserted meanwhile are assigned
    /// to their nearest cell, rows removed meanwhile are dropped — so the
    /// swap is content-preserving under concurrent churn. The reconcile
    /// costs two O(n) hash-probe sweeps (single-digit ms at 100k rows),
    /// paid only when churn actually landed mid-train; with no churn the
    /// install is a plain pointer swap.
    ///
    /// Returns `false` (tier unchanged, trained work discarded) when the
    /// plan came from a *different index value* — e.g. the cache was
    /// cleared or wholesale-replaced between plan and install; reconciling
    /// across that boundary would resurrect stale centroids over a fresh
    /// index.
    #[must_use]
    pub fn install(&mut self, trained: TrainedTier) -> bool {
        if trained.instance != self.instance {
            return false;
        }
        let mut kind = trained.kind;
        if trained.epoch != self.epoch {
            // Additions: in the live tier but unknown to the trained one.
            let mut added: Vec<(u64, Vec<f32>)> = Vec::new();
            self.for_each_row(|id, row| {
                if !kind.contains(id) {
                    added.push((id, row.to_vec()));
                }
            });
            // Removals: trained from a row that has since been deleted.
            let mut removed: Vec<u64> = Vec::new();
            kind.for_each_row(|id, _| {
                if !self.contains(id) {
                    removed.push(id);
                }
            });
            for (id, row) in added {
                // Rows are already in stored (normalized) form.
                kind.insert_stored(id, &row)
                    .expect("reconciled row has the index's dim");
            }
            for id in removed {
                kind.remove(id);
            }
        }
        debug_assert_eq!(kind.len(), self.len());
        self.trained_rows = kind.len();
        self.churn_since_train = 0;
        self.tier = match kind {
            TrainedKind::Ivf(i) => Tier::Ivf(i),
            TrainedKind::IvfQ(q) => Tier::IvfQ(q),
        };
        true
    }

    /// Visit every `(id, row)` pair in stored form (quantized tier rows
    /// are dequantized on the fly).
    pub(crate) fn for_each_row(&self, f: impl FnMut(u64, &[f32])) {
        match &self.tier {
            Tier::Flat(fl) => fl.for_each_row(f),
            Tier::Ivf(i) => i.for_each_row(f),
            Tier::IvfQ(q) => q.for_each_row(f),
        }
    }

    /// Insert a row already in stored form (pre-normalized for cosine),
    /// verbatim — the replication apply/replay path, where rows shipped or
    /// journaled in stored form must land bit-identical on every replica.
    /// Counts as churn exactly like [`VectorIndex::insert`].
    pub(crate) fn insert_stored(&mut self, id: u64, row: &[f32]) -> Result<()> {
        match &mut self.tier {
            Tier::Flat(f) => f.insert_stored(id, row)?,
            Tier::Ivf(i) => i.insert_stored(id, row)?,
            Tier::IvfQ(q) => q.insert_stored(id, row)?,
        }
        self.epoch += 1;
        self.churn_since_train += 1;
        Ok(())
    }

    // ----------------------------------------------------------- snapshot

    /// Durable image: the flat tier writes LBV2 unchanged (old readers
    /// keep working); the IVF tier writes LBV3 so a restore skips
    /// training; the quantized tier writes LBV4 so a restore additionally
    /// skips *reading the corpus* (the code region is mmap'd lazily on
    /// unix). All are written + fsynced like [`FlatIndex::save`].
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        match &self.tier {
            Tier::Flat(f) => f.save(path),
            Tier::IvfQ(q) => {
                let (ids, scales, assignments, codes) = q.export_quantized_parts();
                let dim = q.dim();
                let centroids = q.centroids();
                let mut meta: Vec<u8> =
                    Vec::with_capacity(ids.len() * 16 + centroids.len() * 4);
                for id in &ids {
                    meta.extend_from_slice(&id.to_le_bytes());
                }
                for a in &assignments {
                    meta.extend_from_slice(&a.to_le_bytes());
                }
                for s in &scales {
                    meta.extend_from_slice(&s.to_le_bytes());
                }
                for c in centroids {
                    meta.extend_from_slice(&c.to_le_bytes());
                }
                let codes_off = align_up_4k(LBV4_HEADER + meta.len());
                let code_bytes = codes_as_bytes(&codes);
                let mut out: Vec<u8> = Vec::with_capacity(codes_off + code_bytes.len());
                out.extend_from_slice(LBV4_MAGIC);
                out.extend((dim as u32).to_le_bytes());
                out.push(match q.metric() {
                    Metric::Cosine => 0,
                    Metric::Dot => 1,
                    Metric::L2 => 2,
                });
                out.extend((ids.len() as u64).to_le_bytes());
                out.extend((q.nlist() as u32).to_le_bytes());
                out.extend((q.nprobe as u32).to_le_bytes());
                out.extend((codes_off as u64).to_le_bytes());
                out.extend(crate::util::fnv1a(&meta).to_le_bytes());
                out.extend(crate::util::fnv1a(code_bytes).to_le_bytes());
                out.extend_from_slice(&meta);
                out.resize(codes_off, 0);
                out.extend_from_slice(code_bytes);
                let mut f = std::fs::File::create(path)?;
                std::io::Write::write_all(&mut f, &out)?;
                f.sync_all()?;
                Ok(())
            }
            Tier::Ivf(i) => {
                let (ids, rows, assignments) = i.export_parts();
                let dim = i.dim();
                let nlist = i.nlist();
                let centroids = i.centroids();
                let mut payload: Vec<u8> = Vec::with_capacity(
                    ids.len() * 8 + rows.len() * 4 + assignments.len() * 4 + centroids.len() * 4,
                );
                for id in &ids {
                    payload.extend_from_slice(&id.to_le_bytes());
                }
                for v in &rows {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                for a in &assignments {
                    payload.extend_from_slice(&a.to_le_bytes());
                }
                for c in centroids {
                    payload.extend_from_slice(&c.to_le_bytes());
                }
                let mut out: Vec<u8> = Vec::with_capacity(LBV3_HEADER + payload.len());
                out.extend_from_slice(LBV3_MAGIC);
                out.extend((dim as u32).to_le_bytes());
                out.push(match i.metric() {
                    Metric::Cosine => 0,
                    Metric::Dot => 1,
                    Metric::L2 => 2,
                });
                out.extend((ids.len() as u64).to_le_bytes());
                out.extend((nlist as u32).to_le_bytes());
                out.extend((i.nprobe as u32).to_le_bytes());
                out.extend(crate::util::fnv1a(&payload).to_le_bytes());
                out.extend_from_slice(&payload);
                let mut f = std::fs::File::create(path)?;
                std::io::Write::write_all(&mut f, &out)?;
                f.sync_all()?;
                Ok(())
            }
        }
    }

    /// Load a snapshot written by [`AdaptiveIndex::save`] — or by the
    /// pre-adaptive [`FlatIndex::save`] (LBV2 boots as the flat tier).
    ///
    /// LBV2/LBV3 are read whole; an LBV4 file is **mapped** on unix — only
    /// the 4-byte magic and the metadata pages are actually read before
    /// this returns, the code region stays non-resident until queried.
    pub fn load(path: &std::path::Path, cfg: AdaptiveConfig) -> Result<AdaptiveIndex> {
        let mut magic = [0u8; 4];
        let has_magic = {
            let mut f = std::fs::File::open(path)?;
            std::io::Read::read_exact(&mut f, &mut magic).is_ok()
        };
        if has_magic && &magic == LBV4_MAGIC {
            #[cfg(unix)]
            {
                return Self::load_lbv4_mapped(path, cfg);
            }
        }
        // LBV2/LBV3 (and sub-4-byte files, which fail with the LBV2
        // reader's own error) — plus LBV4 on non-unix, read eagerly.
        let bytes = std::fs::read(path)?;
        Self::from_snapshot_bytes(&bytes, cfg)
    }

    pub(crate) fn from_snapshot_bytes(bytes: &[u8], cfg: AdaptiveConfig) -> Result<AdaptiveIndex> {
        if bytes.len() >= 4 && &bytes[0..4] == LBV4_MAGIC {
            return Self::from_lbv4_bytes(bytes, cfg);
        }
        if bytes.len() >= 4 && &bytes[0..4] == LBV3_MAGIC {
            return Self::from_lbv3_bytes(bytes, cfg);
        }
        // Anything else (including short/corrupt data) goes through the
        // LBV2 reader, whose validation errors already name the problem.
        let flat = FlatIndex::from_snapshot_bytes(bytes)?;
        Ok(AdaptiveIndex::from_flat(flat, cfg))
    }

    fn from_lbv3_bytes(bytes: &[u8], cfg: AdaptiveConfig) -> Result<AdaptiveIndex> {
        if bytes.len() < LBV3_HEADER {
            bail!(
                "truncated LBV3 snapshot: {} bytes, header is {LBV3_HEADER}",
                bytes.len()
            );
        }
        let dim = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        let metric = match bytes[8] {
            0 => Metric::Cosine,
            1 => Metric::Dot,
            2 => Metric::L2,
            m => bail!("bad metric tag {m}"),
        };
        let count = u64::from_le_bytes(bytes[9..17].try_into()?) as usize;
        let nlist = u32::from_le_bytes(bytes[17..21].try_into()?) as usize;
        let nprobe = u32::from_le_bytes(bytes[21..25].try_into()?) as usize;
        let crc = u64::from_le_bytes(bytes[25..33].try_into()?);
        // Validate the declared geometry against the byte length before
        // slicing — reject both short data and trailing garbage.
        let want = (|| {
            let ids = count.checked_mul(8)?;
            let rows = count.checked_mul(dim)?.checked_mul(4)?;
            let assigns = count.checked_mul(4)?;
            let cents = nlist.checked_mul(dim)?.checked_mul(4)?;
            LBV3_HEADER
                .checked_add(ids)?
                .checked_add(rows)?
                .checked_add(assigns)?
                .checked_add(cents)
        })()
        .ok_or_else(|| {
            anyhow::anyhow!("LBV3 snapshot header overflows: count={count} dim={dim} nlist={nlist}")
        })?;
        if bytes.len() != want {
            bail!(
                "corrupt LBV3 snapshot: {} bytes for count={count} dim={dim} nlist={nlist} \
                 (expected {want})",
                bytes.len()
            );
        }
        if crate::util::fnv1a(&bytes[LBV3_HEADER..]) != crc {
            bail!("corrupt LBV3 snapshot: payload checksum mismatch");
        }
        let ids_end = LBV3_HEADER + count * 8;
        let rows_end = ids_end + count * dim * 4;
        let assigns_end = rows_end + count * 4;
        let mut ids = Vec::with_capacity(count);
        for c in bytes[LBV3_HEADER..ids_end].chunks_exact(8) {
            ids.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let mut rows = Vec::with_capacity(count * dim);
        for c in bytes[ids_end..rows_end].chunks_exact(4) {
            rows.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        let mut assignments = Vec::with_capacity(count);
        for c in bytes[rows_end..assigns_end].chunks_exact(4) {
            assignments.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        let mut centroids = Vec::with_capacity(nlist * dim);
        for c in bytes[assigns_end..].chunks_exact(4) {
            centroids.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        let ivf =
            IvfIndex::from_trained_parts(dim, metric, nprobe, centroids, ids, rows, &assignments)?;
        let trained_rows = ivf.len();
        Ok(AdaptiveIndex {
            cfg,
            tier: Tier::Ivf(ivf),
            instance: fresh_instance(),
            epoch: 0,
            trained_rows,
            churn_since_train: 0,
        })
    }

    /// Eager LBV4 reader: all bytes in memory, **both** checksums verified
    /// (the non-unix fallback, and what the corruption tests exercise).
    fn from_lbv4_bytes(bytes: &[u8], cfg: AdaptiveConfig) -> Result<AdaptiveIndex> {
        let meta = Lbv4Meta::parse(bytes)?;
        if crate::util::fnv1a(&bytes[meta.codes_off..]) != meta.codes_crc {
            bail!("corrupt LBV4 snapshot: codes checksum mismatch");
        }
        let codes_off = meta.codes_off;
        Self::from_lbv4_meta(meta, CodesSource::Eager(&bytes[codes_off..]), cfg)
    }

    /// Lazy LBV4 reader: maps the file, parses + checksums the metadata
    /// pages only, and hands the quantized tier mmap-backed cells. Returns
    /// before any code byte is resident; `codes_crc` stays unverified by
    /// design (reading the region to hash it would defeat the laziness —
    /// it is in the header for offline audits and the eager path).
    #[cfg(unix)]
    fn load_lbv4_mapped(path: &std::path::Path, cfg: AdaptiveConfig) -> Result<AdaptiveIndex> {
        let f = std::fs::File::open(path)?;
        let map = Arc::new(MmapRegion::map_file(&f)?);
        let meta = Lbv4Meta::parse(map.as_bytes())?;
        let codes_off = meta.codes_off;
        Self::from_lbv4_meta(
            meta,
            CodesSource::Mapped {
                map: Arc::clone(&map),
                codes_off,
            },
            cfg,
        )
    }

    fn from_lbv4_meta(
        meta: Lbv4Meta,
        codes: CodesSource<'_>,
        cfg: AdaptiveConfig,
    ) -> Result<AdaptiveIndex> {
        let q = QuantIvfIndex::from_grouped_parts(
            meta.dim,
            meta.metric,
            meta.nprobe,
            meta.centroids,
            meta.ids,
            meta.scales,
            &meta.assignments,
            codes,
        )?;
        let trained_rows = q.len();
        Ok(AdaptiveIndex {
            cfg,
            tier: Tier::IvfQ(q),
            instance: fresh_instance(),
            epoch: 0,
            trained_rows,
            churn_since_train: 0,
        })
    }
}

/// Parsed LBV4 header + metadata payload (everything except the codes).
struct Lbv4Meta {
    dim: usize,
    metric: Metric,
    nprobe: usize,
    codes_off: usize,
    codes_crc: u64,
    ids: Vec<u64>,
    scales: Vec<f32>,
    assignments: Vec<u32>,
    centroids: Vec<f32>,
}

impl Lbv4Meta {
    /// Parse and validate header + metadata from the whole file image
    /// (owned bytes or an mmap — on a map, only metadata pages fault in).
    /// Checks: section arithmetic (overflow-safe), the stored `codes_off`
    /// against the one the geometry implies, exact total file size, and
    /// the metadata checksum. Code bytes are *not* touched.
    fn parse(bytes: &[u8]) -> Result<Lbv4Meta> {
        if bytes.len() < LBV4_HEADER {
            bail!(
                "truncated LBV4 snapshot: {} bytes, header is {LBV4_HEADER}",
                bytes.len()
            );
        }
        let dim = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        let metric = match bytes[8] {
            0 => Metric::Cosine,
            1 => Metric::Dot,
            2 => Metric::L2,
            m => bail!("bad metric tag {m}"),
        };
        let count = u64::from_le_bytes(bytes[9..17].try_into()?) as usize;
        let nlist = u32::from_le_bytes(bytes[17..21].try_into()?) as usize;
        let nprobe = u32::from_le_bytes(bytes[21..25].try_into()?) as usize;
        let codes_off = u64::from_le_bytes(bytes[25..33].try_into()?);
        let meta_crc = u64::from_le_bytes(bytes[33..41].try_into()?);
        let codes_crc = u64::from_le_bytes(bytes[41..49].try_into()?);
        let (meta_len, codes_len) = (|| {
            let ids = count.checked_mul(8)?;
            let assigns = count.checked_mul(4)?;
            let scales = count.checked_mul(4)?;
            let cents = nlist.checked_mul(dim)?.checked_mul(4)?;
            let meta_len = ids.checked_add(assigns)?.checked_add(scales)?.checked_add(cents)?;
            let codes_len = count.checked_mul(dim)?;
            Some((meta_len, codes_len))
        })()
        .ok_or_else(|| {
            anyhow::anyhow!("LBV4 snapshot header overflows: count={count} dim={dim} nlist={nlist}")
        })?;
        let want_off = LBV4_HEADER
            .checked_add(meta_len)
            .map(align_up_4k)
            .ok_or_else(|| anyhow::anyhow!("LBV4 snapshot header overflows: meta={meta_len}"))?;
        if codes_off != want_off as u64 {
            bail!("corrupt LBV4 snapshot: codes_off {codes_off}, geometry implies {want_off}");
        }
        let codes_off = want_off;
        let want_total = codes_off.checked_add(codes_len).ok_or_else(|| {
            anyhow::anyhow!("LBV4 snapshot header overflows: codes_off={codes_off}")
        })?;
        if bytes.len() != want_total {
            bail!(
                "corrupt LBV4 snapshot: {} bytes for count={count} dim={dim} nlist={nlist} \
                 (expected {want_total})",
                bytes.len()
            );
        }
        let meta_bytes = &bytes[LBV4_HEADER..LBV4_HEADER + meta_len];
        if crate::util::fnv1a(meta_bytes) != meta_crc {
            bail!("corrupt LBV4 snapshot: metadata checksum mismatch");
        }
        let ids_end = count * 8;
        let assigns_end = ids_end + count * 4;
        let scales_end = assigns_end + count * 4;
        let mut ids = Vec::with_capacity(count);
        for c in meta_bytes[..ids_end].chunks_exact(8) {
            ids.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let mut assignments = Vec::with_capacity(count);
        for c in meta_bytes[ids_end..assigns_end].chunks_exact(4) {
            assignments.push(u32::from_le_bytes(c.try_into().unwrap()));
        }
        let mut scales = Vec::with_capacity(count);
        for c in meta_bytes[assigns_end..scales_end].chunks_exact(4) {
            scales.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        let mut centroids = Vec::with_capacity(nlist * dim);
        for c in meta_bytes[scales_end..].chunks_exact(4) {
            centroids.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        Ok(Lbv4Meta {
            dim,
            metric,
            nprobe,
            codes_off,
            codes_crc,
            ids,
            scales,
            assignments,
            centroids,
        })
    }
}

impl VectorIndex for AdaptiveIndex {
    fn dim(&self) -> usize {
        match &self.tier {
            Tier::Flat(f) => f.dim(),
            Tier::Ivf(i) => i.dim(),
            Tier::IvfQ(q) => q.dim(),
        }
    }

    fn len(&self) -> usize {
        match &self.tier {
            Tier::Flat(f) => f.len(),
            Tier::Ivf(i) => i.len(),
            Tier::IvfQ(q) => q.len(),
        }
    }

    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()> {
        match &mut self.tier {
            Tier::Flat(f) => f.insert(id, vector)?,
            Tier::Ivf(i) => i.insert(id, vector)?,
            Tier::IvfQ(q) => q.insert(id, vector)?,
        }
        self.epoch += 1;
        self.churn_since_train += 1;
        Ok(())
    }

    fn remove(&mut self, id: u64) -> bool {
        let removed = match &mut self.tier {
            Tier::Flat(f) => f.remove(id),
            Tier::Ivf(i) => i.remove(id),
            Tier::IvfQ(q) => q.remove(id),
        };
        if removed {
            self.epoch += 1;
            self.churn_since_train += 1;
        }
        removed
    }

    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit> {
        self.search_effort(query, k, min_score, 0).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::corpus;
    use crate::util::prop::forall;

    fn small_cfg(threshold: usize) -> AdaptiveConfig {
        AdaptiveConfig {
            migrate_threshold: threshold,
            // Out of reach: existing tests exercise the f32 IVF tier; the
            // quantized-tier tests below override this explicitly.
            quantize_threshold: usize::MAX,
            nprobe: 8,
            kmeans_iters: 3,
            train_sample: 4096,
            retrain_fraction: 0.5,
            seed: 0x5EED,
        }
    }

    fn rand_vec(r: &mut Rng, dim: usize) -> Vec<f32> {
        (0..dim).map(|_| r.normal() as f32).collect()
    }

    /// Points around well-separated centers — the workload shape IVF is
    /// built for (cached prompts cluster by topic). Same RNG call sequence
    /// as the pre-PR-6 inline generator, so seeded corpora (and the recall
    /// numbers gated on them) are bit-identical.
    fn clustered(seed: u64, n: usize, dim: usize, centers: usize) -> Vec<(u64, Vec<f32>)> {
        corpus::clustered_pairs(seed, n, dim, centers, 8.0, 0.4)
    }

    fn migrated(data: &[(u64, Vec<f32>)], dim: usize, cfg: AdaptiveConfig) -> AdaptiveIndex {
        let mut adaptive = AdaptiveIndex::new(dim, Metric::Cosine, cfg);
        for (id, v) in data {
            adaptive.insert(*id, v).unwrap();
        }
        let plan = adaptive.rebuild_plan().expect("above threshold");
        assert!(adaptive.install(plan.train()));
        assert_eq!(adaptive.stats().tier, "ivf");
        assert!(adaptive.stats().trained);
        adaptive
    }

    /// Below the migration threshold the adaptive index IS the flat index:
    /// identical hit lists with bit-identical scores.
    #[test]
    fn prop_flat_tier_bit_exact_parity() {
        forall(
            71,
            25,
            |r| {
                let dim = 16;
                let n = 1 + r.below(300);
                let mut flat = FlatIndex::new(dim, Metric::Cosine);
                let mut adaptive =
                    AdaptiveIndex::new(dim, Metric::Cosine, small_cfg(100_000));
                for i in 0..n {
                    let v = rand_vec(r, dim);
                    flat.insert(i as u64, &v).unwrap();
                    adaptive.insert(i as u64, &v).unwrap();
                }
                // Interleave removes so slot layouts stay in lockstep.
                for i in (0..n).step_by(7) {
                    flat.remove(i as u64);
                    adaptive.remove(i as u64);
                }
                let q = rand_vec(r, dim);
                (flat, adaptive, q)
            },
            |(flat, adaptive, q)| {
                assert_eq!(adaptive.stats().tier, "flat");
                for (k, min) in [(1usize, f32::MIN), (4, f32::MIN), (16, 0.2)] {
                    let a = flat.search(q, k, min);
                    let b = adaptive.search(q, k, min);
                    if a.len() != b.len() {
                        return false;
                    }
                    for (x, y) in a.iter().zip(&b) {
                        if x.id != y.id || x.score.to_bits() != y.score.to_bits() {
                            return false;
                        }
                    }
                }
                true
            },
        );
    }

    /// Above the threshold on clustered data, the migrated tier keeps
    /// recall@4 >= 0.95 against flat ground truth at base effort.
    #[test]
    fn migrated_recall_at_4_clustered_20k() {
        let dim = 32;
        let data = clustered(0xC0FFEE, 20_000, dim, 64);
        let mut flat = FlatIndex::new(dim, Metric::Cosine);
        for (id, v) in &data {
            flat.insert(*id, v).unwrap();
        }
        let adaptive = migrated(&data, dim, small_cfg(1000));
        let mut rng = Rng::new(0xFACE);
        let mut found = 0usize;
        let mut total = 0usize;
        for _ in 0..60 {
            let (_, base) = rng.choice(&data).clone();
            let q: Vec<f32> = base
                .iter()
                .map(|x| x + rng.normal() as f32 * 0.1)
                .collect();
            let truth: Vec<u64> = flat.search(&q, 4, f32::MIN).iter().map(|h| h.id).collect();
            let got: Vec<u64> = adaptive.search(&q, 4, f32::MIN).iter().map(|h| h.id).collect();
            total += truth.len();
            found += truth.iter().filter(|t| got.contains(t)).count();
        }
        let recall = found as f64 / total as f64;
        assert!(recall >= 0.95, "recall@4={recall}");
    }

    /// Effort widening converges to the exhaustive all-cells probe, whose
    /// hit set equals flat ground truth exactly (same rows, same kernel).
    #[test]
    fn exhaustive_effort_matches_flat_ground_truth() {
        let dim = 16;
        let data = clustered(0xBEEF, 3000, dim, 16);
        let mut flat = FlatIndex::new(dim, Metric::Cosine);
        for (id, v) in &data {
            flat.insert(*id, v).unwrap();
        }
        let adaptive = migrated(&data, dim, small_cfg(500));
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let q = rand_vec(&mut rng, dim);
            // Find the first exhaustive effort level.
            let mut effort = 0;
            let (hits, exhaustive) = loop {
                let (h, ex) = adaptive.search_effort(&q, 8, f32::MIN, effort);
                if ex {
                    break (h, ex);
                }
                effort += 1;
                assert!(effort < 32, "effort never became exhaustive");
            };
            assert!(exhaustive);
            let truth = flat.search(&q, 8, f32::MIN);
            // Same rows, same kernel — but a row's dot4-block position
            // differs between layouts, so compare ids exactly and scores
            // to last-ulp tolerance rather than bit-for-bit.
            let mut a: Vec<u64> = hits.iter().map(|h| h.id).collect();
            let mut b: Vec<u64> = truth.iter().map(|h| h.id).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b);
            let score_of = |hs: &[Hit], id: u64| {
                hs.iter().find(|h| h.id == id).unwrap().score
            };
            for id in &a {
                let d = (score_of(&hits, *id) - score_of(&truth, *id)).abs();
                assert!(d < 1e-5, "score drift {d} for id {id}");
            }
        }
    }

    /// Removing a row after migration and re-adding the same vector gives
    /// search results equivalent to never having removed it.
    #[test]
    fn remove_readd_equivalence_after_migration() {
        let dim = 16;
        let data = clustered(0xABBA, 2000, dim, 12);
        let mut adaptive = migrated(&data, dim, small_cfg(500));
        let nlist = adaptive.stats().nlist;
        let q = {
            let mut rng = Rng::new(99);
            rand_vec(&mut rng, dim)
        };
        let before = adaptive.search_effort(&q, 10, f32::MIN, 32).0;
        for (id, v) in data.iter().take(50) {
            assert!(adaptive.remove(*id));
            assert!(!adaptive.contains(*id));
            adaptive.insert(*id, v).unwrap();
            assert!(adaptive.contains(*id));
        }
        assert_eq!(adaptive.len(), data.len());
        assert_eq!(adaptive.stats().nlist, nlist, "no retrain happened");
        let after = adaptive.search_effort(&q, 10, f32::MIN, 32).0;
        // Re-added rows land back in the same cell (same centroids, same
        // normalize) but at a different slot, so scores can wobble by an
        // ulp — same ids, tolerance on scores.
        let ids = |hs: &[Hit]| {
            let mut v: Vec<u64> = hs.iter().map(|h| h.id).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(ids(&before), ids(&after));
        for b in &before {
            let a = after.iter().find(|h| h.id == b.id).unwrap();
            assert!((a.score - b.score).abs() < 1e-5);
        }
    }

    /// Mutations that land between rebuild_plan and install are reconciled
    /// into the trained tier: nothing lost, nothing resurrected.
    #[test]
    fn install_reconciles_concurrent_churn() {
        let dim = 8;
        let data = clustered(0xD00D, 1200, dim, 8);
        let mut adaptive = AdaptiveIndex::new(dim, Metric::Cosine, small_cfg(500));
        for (id, v) in &data {
            adaptive.insert(*id, v).unwrap();
        }
        let plan = adaptive.rebuild_plan().unwrap();
        // Churn after the plan was taken.
        for id in 0..40u64 {
            assert!(adaptive.remove(id));
        }
        let mut rng = Rng::new(5);
        for id in 5000..5030u64 {
            adaptive.insert(id, &rand_vec(&mut rng, dim)).unwrap();
        }
        let trained = plan.train();
        assert!(adaptive.install(trained), "same index: reconcile, not refuse");
        assert_eq!(adaptive.len(), 1200 - 40 + 30);
        for id in 0..40u64 {
            assert!(!adaptive.contains(id), "removed id {id} resurrected");
        }
        for id in 5000..5030u64 {
            assert!(adaptive.contains(id), "reconciled insert {id} lost");
            let (hits, _) = adaptive.search_effort(
                &{
                    // exhaustive probe for the id's own row
                    let mut found = None;
                    adaptive.for_each_row(|rid, row| {
                        if rid == id {
                            found = Some(row.to_vec());
                        }
                    });
                    found.unwrap()
                },
                1,
                f32::MIN,
                32,
            );
            assert_eq!(hits[0].id, id, "reconciled row not retrievable");
        }
    }

    /// Drift-triggered retrain: enough churn re-arms needs_rebuild.
    #[test]
    fn churn_triggers_retrain() {
        let dim = 8;
        let data = clustered(0xF00D, 800, dim, 8);
        let mut adaptive = migrated(&data, dim, small_cfg(400));
        assert!(!adaptive.needs_rebuild());
        let mut rng = Rng::new(17);
        for id in 9000..9000 + 500u64 {
            adaptive.insert(id, &rand_vec(&mut rng, dim)).unwrap();
        }
        assert!(adaptive.needs_rebuild(), "500/800 churn is past 0.5 drift");
        let plan = adaptive.rebuild_plan().unwrap();
        assert!(adaptive.install(plan.train()));
        assert!(!adaptive.needs_rebuild());
        assert_eq!(adaptive.len(), 1300);
    }

    /// A plan taken from an index that was then wholesale-replaced (the
    /// cache's clear()) must be refused, not reconciled into the fresh
    /// index — stale centroids never demote a cleared cache off the
    /// bit-exact flat tier.
    #[test]
    fn install_refuses_replaced_index() {
        let dim = 8;
        let data = clustered(0xCAFE, 800, dim, 8);
        let mut adaptive = AdaptiveIndex::new(dim, Metric::Cosine, small_cfg(400));
        for (id, v) in &data {
            adaptive.insert(*id, v).unwrap();
        }
        let plan = adaptive.rebuild_plan().unwrap();
        let trained = plan.train();
        // clear(): a brand-new index value takes this one's place.
        adaptive = AdaptiveIndex::new(dim, Metric::Cosine, small_cfg(400));
        adaptive.insert(1, &data[0].1).unwrap();
        assert!(!adaptive.install(trained), "stale trained tier refused");
        assert_eq!(adaptive.stats().tier, "flat");
        assert_eq!(adaptive.len(), 1);
    }

    /// LBV3 round-trip: a migrated index restores trained (no k-means on
    /// load) with bit-identical hits; LBV2 still loads as the flat tier.
    #[test]
    fn snapshot_roundtrip_lbv3_and_lbv2() {
        let dim = 16;
        let dir = std::env::temp_dir().join("llmbridge_adaptive_snap");
        std::fs::create_dir_all(&dir).unwrap();

        let data = clustered(0x1CE, 1500, dim, 10);
        let adaptive = migrated(&data, dim, small_cfg(500));
        let p3 = dir.join("adaptive.lbv3.bin");
        adaptive.save(&p3).unwrap();
        let back = AdaptiveIndex::load(&p3, small_cfg(500)).unwrap();
        assert_eq!(back.stats(), adaptive.stats());
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let q = rand_vec(&mut rng, dim);
            let a = adaptive.search(&q, 5, f32::MIN);
            let b = back.search(&q, 5, f32::MIN);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }

        // Flat tier writes plain LBV2, readable by both loaders.
        let mut small = AdaptiveIndex::new(dim, Metric::Cosine, small_cfg(100_000));
        for (id, v) in data.iter().take(100) {
            small.insert(*id, v).unwrap();
        }
        let p2 = dir.join("adaptive.lbv2.bin");
        small.save(&p2).unwrap();
        assert_eq!(FlatIndex::load(&p2).unwrap().len(), 100);
        let back2 = AdaptiveIndex::load(&p2, small_cfg(100_000)).unwrap();
        assert_eq!(back2.stats().tier, "flat");
        assert_eq!(back2.len(), 100);
    }

    #[test]
    fn load_rejects_corrupt_lbv3() {
        let dim = 8;
        let dir = std::env::temp_dir().join("llmbridge_adaptive_snap");
        std::fs::create_dir_all(&dir).unwrap();
        let data = clustered(0xBAD, 600, dim, 6);
        let adaptive = migrated(&data, dim, small_cfg(300));
        let path = dir.join("corrupt.lbv3.bin");
        adaptive.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        assert_eq!(&good[0..4], LBV3_MAGIC);

        // Truncated mid-section.
        let err =
            AdaptiveIndex::from_snapshot_bytes(&good[..good.len() - 3], small_cfg(300))
                .unwrap_err();
        assert!(err.to_string().contains("corrupt LBV3"), "{err}");
        // Trailing garbage.
        let mut trailing = good.clone();
        trailing.extend_from_slice(&[1, 2, 3]);
        assert!(AdaptiveIndex::from_snapshot_bytes(&trailing, small_cfg(300)).is_err());
        // In-range payload corruption: an assignment flipped to another
        // (valid) cell would silently lose recall — the payload checksum
        // catches it before any structural validation could be fooled.
        let count = adaptive.len();
        let assigns_start = LBV3_HEADER + count * 8 + count * dim * 4;
        let mut bad = good.clone();
        bad[assigns_start] ^= 0x01;
        let err = AdaptiveIndex::from_snapshot_bytes(&bad, small_cfg(300)).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Same for a row float bit-flip.
        let mut bad = good.clone();
        bad[LBV3_HEADER + count * 8 + 2] ^= 0x40;
        let err = AdaptiveIndex::from_snapshot_bytes(&bad, small_cfg(300)).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // Shorter than the LBV3 header falls through to the LBV2 reader's
        // validation (bad magic / truncated).
        assert!(AdaptiveIndex::from_snapshot_bytes(&good[..3], small_cfg(300)).is_err());
    }

    /// The corpus climbs all three tiers through the normal maintenance
    /// path: flat below migrate_threshold, f32 IVF between the thresholds,
    /// i8 IVF once it outgrows quantize_threshold — and the promotion is
    /// armed by size alone, not churn drift.
    #[test]
    fn promotes_flat_to_ivf_to_quantized() {
        let dim = 16;
        let mut cfg = small_cfg(300);
        cfg.quantize_threshold = 900;
        // Drift can't fire: promotions below must come from the size arms.
        cfg.retrain_fraction = 100.0;
        let data = clustered(0x9A7, 1200, dim, 8);
        let mut adaptive = AdaptiveIndex::new(dim, Metric::Cosine, cfg);
        for (id, v) in data.iter().take(400) {
            adaptive.insert(*id, v).unwrap();
        }
        assert!(adaptive.needs_rebuild(), "flat past migrate_threshold");
        let plan = adaptive.rebuild_plan().unwrap();
        assert!(adaptive.install(plan.train()));
        assert_eq!(adaptive.stats().tier, "ivf", "below quantize_threshold");
        assert!(!adaptive.needs_rebuild());

        for (id, v) in data.iter().skip(400) {
            adaptive.insert(*id, v).unwrap();
        }
        assert!(adaptive.needs_rebuild(), "ivf past quantize_threshold");
        let plan = adaptive.rebuild_plan().unwrap();
        assert!(adaptive.install(plan.train()));
        let stats = adaptive.stats();
        assert_eq!(stats.tier, "ivf_i8");
        assert!(stats.trained);
        assert_eq!(stats.rows, 1200);
        assert_eq!(stats.vector_bytes, 1200 * (dim + 4));
        assert!(!adaptive.needs_rebuild(), "freshly promoted: no drift");
        assert_eq!(adaptive.lazy_cells(), 0, "built in memory, not mapped");
        // The tier stays functional under churn and keeps O(1) contains.
        assert!(adaptive.remove(data[0].0));
        assert!(!adaptive.contains(data[0].0));
        adaptive.insert(data[0].0, &data[0].1).unwrap();
        assert!(adaptive.contains(data[0].0));
    }

    /// The acceptance gate for the quantized tier: on a 20k clustered
    /// corpus, recall@4 against exact f32 flat ground truth stays ≥ 0.95
    /// while the vector region shrinks ≥ 3.5x versus f32 rows. 4 points
    /// per cluster makes the true top-4 a whole, well-separated cluster —
    /// see `util::corpus::balanced_clustered_pairs`.
    #[test]
    fn quantized_recall_at_4_and_bytes_cut_clustered_20k() {
        let dim = 32;
        let data = corpus::balanced_clustered_pairs(0xC0FFEE, 5000, 4, dim, 8.0, 0.4);
        let mut flat = FlatIndex::new(dim, Metric::Cosine);
        for (id, v) in &data {
            flat.insert(*id, v).unwrap();
        }
        let mut cfg = small_cfg(1000);
        cfg.quantize_threshold = 1000;
        let adaptive = migrated_quantized(&data, dim, cfg);
        let stats = adaptive.stats();
        let cut = (stats.rows * dim * 4) as f64 / stats.vector_bytes as f64;
        assert!(cut >= 3.5, "vector-region cut only {cut:.2}x");

        let mut rng = Rng::new(0xFACE);
        let mut found = 0usize;
        let mut total = 0usize;
        for _ in 0..60 {
            let (_, base) = &data[rng.below(data.len())];
            let q = corpus::perturbed(&mut rng, base, 0.1);
            let truth: Vec<u64> = flat.search(&q, 4, f32::MIN).iter().map(|h| h.id).collect();
            let got: Vec<u64> = adaptive.search(&q, 4, f32::MIN).iter().map(|h| h.id).collect();
            total += truth.len();
            found += truth.iter().filter(|t| got.contains(t)).count();
        }
        let recall = found as f64 / total as f64;
        assert!(recall >= 0.95, "recall@4={recall}");
    }

    /// Like [`migrated`] but with the quantize threshold set so the train
    /// lands on the i8 tier directly.
    fn migrated_quantized(
        data: &[(u64, Vec<f32>)],
        dim: usize,
        cfg: AdaptiveConfig,
    ) -> AdaptiveIndex {
        let mut adaptive = AdaptiveIndex::new(dim, Metric::Cosine, cfg);
        for (id, v) in data {
            adaptive.insert(*id, v).unwrap();
        }
        let plan = adaptive.rebuild_plan().expect("above threshold");
        assert!(adaptive.install(plan.train()));
        assert_eq!(adaptive.stats().tier, "ivf_i8");
        adaptive
    }

    /// LBV4 round-trip: a quantized index restores bit-identically. On
    /// unix the restore is lazy — cells stay mmap-backed until churn
    /// materializes them one at a time.
    #[test]
    fn snapshot_roundtrip_lbv4() {
        let dim = 16;
        let dir = std::env::temp_dir().join("llmbridge_adaptive_snap");
        std::fs::create_dir_all(&dir).unwrap();
        let data = corpus::balanced_clustered_pairs(0x1CE4, 400, 4, dim, 8.0, 0.4);
        let mut cfg = small_cfg(500);
        cfg.quantize_threshold = 500;
        let adaptive = migrated_quantized(&data, dim, cfg.clone());
        let path = dir.join("adaptive.lbv4.bin");
        adaptive.save(&path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[0..4], LBV4_MAGIC);
        let back = AdaptiveIndex::load(&path, cfg).unwrap();
        assert_eq!(back.stats(), adaptive.stats());
        #[cfg(unix)]
        {
            assert!(
                back.lazy_cells() > 0,
                "unix load should leave cells mmap-backed"
            );
        }
        // Same i8 codes + scales + centroids → identical probe order and
        // rescore arithmetic: hits are bit-exact live vs restored.
        let mut rng = Rng::new(3);
        for _ in 0..10 {
            let q = rand_vec(&mut rng, dim);
            let a = adaptive.search(&q, 5, f32::MIN);
            let b = back.search(&q, 5, f32::MIN);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
        // Copy-on-write: one insert materializes exactly the touched cell.
        let mut back = back;
        let before = back.lazy_cells();
        back.insert(999_999, &data[0].1).unwrap();
        #[cfg(unix)]
        {
            assert!(back.lazy_cells() < before, "insert must materialize its cell");
            assert!(back.lazy_cells() >= before - 1, "… and only its cell");
        }
        assert!(back.contains(999_999));
        assert!(back.remove(999_999));
        let _ = before;
    }

    #[test]
    fn load_rejects_corrupt_lbv4() {
        let dim = 8;
        let dir = std::env::temp_dir().join("llmbridge_adaptive_snap");
        std::fs::create_dir_all(&dir).unwrap();
        let data = corpus::balanced_clustered_pairs(0xBAD4, 150, 4, dim, 8.0, 0.4);
        let mut cfg = small_cfg(300);
        cfg.quantize_threshold = 300;
        let adaptive = migrated_quantized(&data, dim, cfg.clone());
        let path = dir.join("corrupt.lbv4.bin");
        adaptive.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        assert_eq!(&good[0..4], LBV4_MAGIC);
        let count = adaptive.len();

        // Truncated: code region short.
        let err = AdaptiveIndex::from_snapshot_bytes(&good[..good.len() - 3], small_cfg(300))
            .unwrap_err();
        assert!(err.to_string().contains("corrupt LBV4"), "{err}");
        // Trailing garbage.
        let mut trailing = good.clone();
        trailing.extend_from_slice(&[1, 2, 3]);
        assert!(AdaptiveIndex::from_snapshot_bytes(&trailing, small_cfg(300)).is_err());
        // Metadata bit-flip (an id byte) → metadata checksum.
        let mut bad = good.clone();
        bad[LBV4_HEADER + 1] ^= 0x01;
        let err = AdaptiveIndex::from_snapshot_bytes(&bad, small_cfg(300)).unwrap_err();
        assert!(err.to_string().contains("metadata checksum"), "{err}");
        // Code-region bit-flip → codes checksum (eager path).
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x10;
        let err = AdaptiveIndex::from_snapshot_bytes(&bad, small_cfg(300)).unwrap_err();
        assert!(err.to_string().contains("codes checksum"), "{err}");
        // Un-grouped assignments with a *recomputed* checksum: structural
        // validation must reject what the crc can no longer catch. Set the
        // first row's cell to the last cell id, breaking monotonicity.
        let nlist = adaptive.stats().nlist;
        assert!(nlist > 1);
        let mut bad = good.clone();
        let assigns_start = LBV4_HEADER + count * 8;
        bad[assigns_start..assigns_start + 4]
            .copy_from_slice(&((nlist - 1) as u32).to_le_bytes());
        let meta_len = count * 8 + count * 4 + count * 4 + nlist * dim * 4;
        let crc = crate::util::fnv1a(&bad[LBV4_HEADER..LBV4_HEADER + meta_len]);
        bad[33..41].copy_from_slice(&crc.to_le_bytes());
        let err = AdaptiveIndex::from_snapshot_bytes(&bad, small_cfg(300)).unwrap_err();
        assert!(err.to_string().contains("not cell-grouped"), "{err}");
        // The mapped path (load from a file) rejects metadata corruption
        // too — write the flipped-id image out and load it.
        let mut bad = good.clone();
        bad[LBV4_HEADER + 1] ^= 0x01;
        let bad_path = dir.join("corrupt_mapped.lbv4.bin");
        std::fs::write(&bad_path, &bad).unwrap();
        let err = AdaptiveIndex::load(&bad_path, small_cfg(300)).unwrap_err();
        assert!(err.to_string().contains("metadata checksum"), "{err}");
    }
}
