//! Runtime-dispatched dot-product kernels: portable scalar, x86_64 AVX2,
//! and aarch64 NEON — `std::arch` only, per the anyhow-only dependency
//! policy (no `wide`/`packed_simd`).
//!
//! ## Bit-exactness contract
//!
//! Every SIMD variant computes the **same arithmetic in the same order**
//! as the scalar kernel, so switching variants never changes a score bit:
//!
//! * f32 kernels accumulate 8 independent lanes over `chunks_exact(8)`
//!   (AVX2: one 256-bit register; NEON: two 128-bit registers), multiply
//!   and add as separate IEEE-rounded ops (**no FMA**), reduce the lanes
//!   with an ordered left fold `l0 + l1 + … + l7`, then fold the scalar
//!   remainder in element order.
//! * i8 kernels widen to i32 and accumulate in i32 — integer addition is
//!   associative, so any reduction shape matches the scalar loop exactly.
//!
//! The property tests at the bottom pin this contract per variant with
//! `f32::to_bits` equality; `scripts/ci.sh` additionally re-runs the whole
//! suite with [`FORCE_SCALAR_ENV`] set so the fallback path stays green on
//! machines without AVX2/NEON.
//!
//! Dispatch is decided once per process ([`active_variant`], cached) and
//! can be pinned to the fallback with `LLMBRIDGE_FORCE_SCALAR=1` —
//! `llmbridge probe-backend` reports the decision.

use std::sync::OnceLock;

/// Environment variable that pins dispatch to the scalar fallback when set
/// to `1` (read once, at the first kernel call).
pub const FORCE_SCALAR_ENV: &str = "LLMBRIDGE_FORCE_SCALAR";

/// Which kernel implementation the dispatchers run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// Portable chunked-scalar kernels — the shape the SIMD variants mirror.
    Scalar,
    /// x86_64 AVX2 (256-bit lanes; mul + add, never FMA).
    Avx2,
    /// aarch64 NEON (two 128-bit registers emulating the 8-lane shape).
    Neon,
}

impl KernelVariant {
    pub fn name(self) -> &'static str {
        match self {
            KernelVariant::Scalar => "scalar",
            KernelVariant::Avx2 => "avx2",
            KernelVariant::Neon => "neon",
        }
    }
}

/// The SIMD variant this host supports, ignoring the force-scalar override
/// (`None` when the host has neither AVX2 nor NEON). The parity tests use
/// this directly so they stay meaningful under the override.
pub fn simd_variant() -> Option<KernelVariant> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return Some(KernelVariant::Avx2);
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Some(KernelVariant::Neon);
        }
    }
    None
}

/// The variant the public dispatchers use: hardware-detected once per
/// process, pinned to [`KernelVariant::Scalar`] when [`FORCE_SCALAR_ENV`]
/// is `1`.
pub fn active_variant() -> KernelVariant {
    static ACTIVE: OnceLock<KernelVariant> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        if std::env::var_os(FORCE_SCALAR_ENV).is_some_and(|v| v == "1") {
            KernelVariant::Scalar
        } else {
            simd_variant().unwrap_or(KernelVariant::Scalar)
        }
    })
}

// ------------------------------------------------------------ dispatchers

/// f32 dot product (runtime-dispatched).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with(active_variant(), a, b)
}

/// One query against four consecutive row-major rows (runtime-dispatched).
/// Each output is bit-identical to `dot(q, row_j)` in the same variant.
#[inline]
pub fn dot4(q: &[f32], rows: &[f32], dim: usize) -> [f32; 4] {
    dot4_with(active_variant(), q, rows, dim)
}

/// i8 dot product, widened to i32 (runtime-dispatched; exact in any
/// variant — integer accumulation has no rounding).
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    dot_i8_with(active_variant(), a, b)
}

/// One i8 query against four consecutive i8 rows (runtime-dispatched).
#[inline]
pub fn dot4_i8(q: &[i8], rows: &[i8], dim: usize) -> [i32; 4] {
    dot4_i8_with(active_variant(), q, rows, dim)
}

/// Variant-explicit [`dot`] — the parity tests drive each variant directly.
pub fn dot_with(variant: KernelVariant, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match variant {
        KernelVariant::Scalar => dot_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // Safety: Avx2 is only handed out by detection on this host.
        KernelVariant::Avx2 => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        // Safety: Neon is only handed out by detection on this host.
        KernelVariant::Neon => unsafe { neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Variant-explicit [`dot4`].
pub fn dot4_with(variant: KernelVariant, q: &[f32], rows: &[f32], dim: usize) -> [f32; 4] {
    debug_assert_eq!(q.len(), dim);
    debug_assert_eq!(rows.len(), 4 * dim);
    match variant {
        KernelVariant::Scalar => dot4_scalar(q, rows, dim),
        #[cfg(target_arch = "x86_64")]
        // Safety: Avx2 is only handed out by detection on this host.
        KernelVariant::Avx2 => unsafe { avx2::dot4(q, rows, dim) },
        #[cfg(target_arch = "aarch64")]
        // Safety: Neon is only handed out by detection on this host.
        KernelVariant::Neon => unsafe { neon::dot4(q, rows, dim) },
        _ => dot4_scalar(q, rows, dim),
    }
}

/// Variant-explicit [`dot_i8`].
pub fn dot_i8_with(variant: KernelVariant, a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match variant {
        KernelVariant::Scalar => dot_i8_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        // Safety: Avx2 is only handed out by detection on this host.
        KernelVariant::Avx2 => unsafe { avx2::dot_i8(a, b) },
        #[cfg(target_arch = "aarch64")]
        // Safety: Neon is only handed out by detection on this host.
        KernelVariant::Neon => unsafe { neon::dot_i8(a, b) },
        _ => dot_i8_scalar(a, b),
    }
}

/// Variant-explicit [`dot4_i8`].
pub fn dot4_i8_with(variant: KernelVariant, q: &[i8], rows: &[i8], dim: usize) -> [i32; 4] {
    debug_assert_eq!(q.len(), dim);
    debug_assert_eq!(rows.len(), 4 * dim);
    match variant {
        KernelVariant::Scalar => dot4_i8_scalar(q, rows, dim),
        #[cfg(target_arch = "x86_64")]
        // Safety: Avx2 is only handed out by detection on this host.
        KernelVariant::Avx2 => unsafe { avx2::dot4_i8(q, rows, dim) },
        #[cfg(target_arch = "aarch64")]
        // Safety: Neon is only handed out by detection on this host.
        KernelVariant::Neon => unsafe { neon::dot4_i8(q, rows, dim) },
        _ => dot4_i8_scalar(q, rows, dim),
    }
}

// ------------------------------------------------------------ scalar

/// Chunked multi-accumulator scalar kernel: `chunks_exact` removes the
/// bounds checks that block auto-vectorization, and the 8 independent
/// accumulators are exactly the lane shape of the AVX2/NEON variants —
/// the ordered left-fold reduction is what makes them bit-exact peers.
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for j in 0..8 {
            acc[j] += xa[j] * xb[j];
        }
    }
    let mut s = acc[0];
    for &l in &acc[1..] {
        s += l;
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

fn dot4_scalar(q: &[f32], rows: &[f32], dim: usize) -> [f32; 4] {
    [
        dot_scalar(q, &rows[..dim]),
        dot_scalar(q, &rows[dim..2 * dim]),
        dot_scalar(q, &rows[2 * dim..3 * dim]),
        dot_scalar(q, &rows[3 * dim..4 * dim]),
    ]
}

fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    a.iter().zip(b).map(|(&x, &y)| x as i32 * y as i32).sum()
}

fn dot4_i8_scalar(q: &[i8], rows: &[i8], dim: usize) -> [i32; 4] {
    [
        dot_i8_scalar(q, &rows[..dim]),
        dot_i8_scalar(q, &rows[dim..2 * dim]),
        dot_i8_scalar(q, &rows[2 * dim..3 * dim]),
        dot_i8_scalar(q, &rows[3 * dim..4 * dim]),
    ]
}

// ------------------------------------------------------------ x86_64 AVX2

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// Safety (all fns here): the caller must have verified AVX2 support
    /// via runtime detection before calling.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(c * 8));
            let vb = _mm256_loadu_ps(b.as_ptr().add(c * 8));
            // mul then add, separately rounded — bit-exact vs scalar.
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut s = lanes[0];
        for &l in &lanes[1..] {
            s += l;
        }
        for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
            s += x * y;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4(q: &[f32], rows: &[f32], dim: usize) -> [f32; 4] {
        let chunks = dim / 8;
        let mut acc = [_mm256_setzero_ps(); 4];
        for c in 0..chunks {
            // One query load serves all four rows — the register-blocked
            // form of the flat-scan hot loop.
            let vq = _mm256_loadu_ps(q.as_ptr().add(c * 8));
            for (r, a) in acc.iter_mut().enumerate() {
                let vr = _mm256_loadu_ps(rows.as_ptr().add(r * dim + c * 8));
                *a = _mm256_add_ps(*a, _mm256_mul_ps(vq, vr));
            }
        }
        let mut out = [0.0f32; 4];
        for (r, o) in out.iter_mut().enumerate() {
            let mut lanes = [0.0f32; 8];
            _mm256_storeu_ps(lanes.as_mut_ptr(), acc[r]);
            let mut s = lanes[0];
            for &l in &lanes[1..] {
                s += l;
            }
            let row = &rows[r * dim..(r + 1) * dim];
            for (x, y) in q[chunks * 8..].iter().zip(&row[chunks * 8..]) {
                s += x * y;
            }
            *o = s;
        }
        out
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let chunks = n / 16;
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            // 16 i8 → 16 i16, pairwise multiply-add to 8 i32 lanes.
            let va = _mm_loadu_si128(a.as_ptr().add(c * 16) as *const __m128i);
            let vb = _mm_loadu_si128(b.as_ptr().add(c * 16) as *const __m128i);
            let prod = _mm256_madd_epi16(_mm256_cvtepi8_epi16(va), _mm256_cvtepi8_epi16(vb));
            acc = _mm256_add_epi32(acc, prod);
        }
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
        let mut s: i32 = lanes.iter().sum();
        for (x, y) in a[chunks * 16..].iter().zip(&b[chunks * 16..]) {
            s += *x as i32 * *y as i32;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4_i8(q: &[i8], rows: &[i8], dim: usize) -> [i32; 4] {
        [
            dot_i8(q, &rows[..dim]),
            dot_i8(q, &rows[dim..2 * dim]),
            dot_i8(q, &rows[2 * dim..3 * dim]),
            dot_i8(q, &rows[3 * dim..4 * dim]),
        ]
    }
}

// ------------------------------------------------------------ aarch64 NEON

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// Safety (all fns here): the caller must have verified NEON support
    /// via runtime detection before calling.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        // Two 128-bit accumulators emulate the scalar kernel's 8 lanes.
        let mut acc_lo = vdupq_n_f32(0.0);
        let mut acc_hi = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let pa = a.as_ptr().add(c * 8);
            let pb = b.as_ptr().add(c * 8);
            // mul then add, separately rounded — bit-exact vs scalar.
            acc_lo = vaddq_f32(acc_lo, vmulq_f32(vld1q_f32(pa), vld1q_f32(pb)));
            acc_hi = vaddq_f32(acc_hi, vmulq_f32(vld1q_f32(pa.add(4)), vld1q_f32(pb.add(4))));
        }
        let mut lanes = [0.0f32; 8];
        vst1q_f32(lanes.as_mut_ptr(), acc_lo);
        vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
        let mut s = lanes[0];
        for &l in &lanes[1..] {
            s += l;
        }
        for (x, y) in a[chunks * 8..].iter().zip(&b[chunks * 8..]) {
            s += x * y;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot4(q: &[f32], rows: &[f32], dim: usize) -> [f32; 4] {
        let chunks = dim / 8;
        let mut lo = [vdupq_n_f32(0.0); 4];
        let mut hi = [vdupq_n_f32(0.0); 4];
        for c in 0..chunks {
            let pq = q.as_ptr().add(c * 8);
            let q_lo = vld1q_f32(pq);
            let q_hi = vld1q_f32(pq.add(4));
            for r in 0..4 {
                let pr = rows.as_ptr().add(r * dim + c * 8);
                lo[r] = vaddq_f32(lo[r], vmulq_f32(q_lo, vld1q_f32(pr)));
                hi[r] = vaddq_f32(hi[r], vmulq_f32(q_hi, vld1q_f32(pr.add(4))));
            }
        }
        let mut out = [0.0f32; 4];
        for (r, o) in out.iter_mut().enumerate() {
            let mut lanes = [0.0f32; 8];
            vst1q_f32(lanes.as_mut_ptr(), lo[r]);
            vst1q_f32(lanes.as_mut_ptr().add(4), hi[r]);
            let mut s = lanes[0];
            for &l in &lanes[1..] {
                s += l;
            }
            let row = &rows[r * dim..(r + 1) * dim];
            for (x, y) in q[chunks * 8..].iter().zip(&row[chunks * 8..]) {
                s += x * y;
            }
            *o = s;
        }
        out
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let chunks = n / 16;
        let mut acc = vdupq_n_s32(0);
        for c in 0..chunks {
            let va = vld1q_s8(a.as_ptr().add(c * 16));
            let vb = vld1q_s8(b.as_ptr().add(c * 16));
            // Widening multiplies (i8×i8 → i16), pairwise-accumulated into
            // i32 lanes — exact, like every integer reduction shape.
            let p_lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
            let p_hi = vmull_s8(vget_high_s8(va), vget_high_s8(vb));
            acc = vpadalq_s16(acc, p_lo);
            acc = vpadalq_s16(acc, p_hi);
        }
        let mut s = vaddvq_s32(acc);
        for (x, y) in a[chunks * 16..].iter().zip(&b[chunks * 16..]) {
            s += *x as i32 * *y as i32;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot4_i8(q: &[i8], rows: &[i8], dim: usize) -> [i32; 4] {
        [
            dot_i8(q, &rows[..dim]),
            dot_i8(q, &rows[dim..2 * dim]),
            dot_i8(q, &rows[2 * dim..3 * dim]),
            dot_i8(q, &rows[3 * dim..4 * dim]),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Lengths covering empty, sub-chunk, chunk-aligned, and remainders
    /// for both the 8-lane f32 and 16-lane i8 chunk shapes.
    const LENS: &[usize] = &[0, 1, 7, 8, 9, 15, 16, 17, 31, 32, 63, 64, 65, 127, 128];

    fn f32_vec(r: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| r.normal() as f32).collect()
    }

    fn i8_vec(r: &mut Rng, len: usize) -> Vec<i8> {
        (0..len).map(|_| (r.below(255) as i32 - 127) as i8).collect()
    }

    #[test]
    fn scalar_dot_matches_naive() {
        let mut r = Rng::new(5);
        for &len in LENS {
            let a = f32_vec(&mut r, len);
            let b = f32_vec(&mut r, len);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!(
                (dot_with(KernelVariant::Scalar, &a, &b) - naive).abs() < 1e-3,
                "len={len}"
            );
        }
    }

    #[test]
    fn scalar_dot_i8_matches_naive() {
        let mut r = Rng::new(6);
        for &len in LENS {
            let a = i8_vec(&mut r, len);
            let b = i8_vec(&mut r, len);
            let naive: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
            assert_eq!(dot_i8_with(KernelVariant::Scalar, &a, &b), naive, "len={len}");
        }
    }

    /// The load-bearing parity property: on hosts with a SIMD unit, every
    /// kernel is bit-exact against its scalar twin (f32 via `to_bits`,
    /// i8 exactly). Probes the hardware variant directly, so this stays
    /// meaningful when CI re-runs the suite under LLMBRIDGE_FORCE_SCALAR=1.
    #[test]
    fn prop_simd_kernels_bit_exact_vs_scalar() {
        let Some(v) = simd_variant() else {
            // No AVX2/NEON on this host: dispatch is scalar-only and the
            // parity claim is vacuous here.
            return;
        };
        let mut r = Rng::new(0xD07);
        for &len in LENS {
            for _ in 0..8 {
                let a = f32_vec(&mut r, len);
                let b = f32_vec(&mut r, len);
                let s = dot_with(KernelVariant::Scalar, &a, &b);
                let w = dot_with(v, &a, &b);
                assert_eq!(s.to_bits(), w.to_bits(), "dot len={len} {}", v.name());

                let ia = i8_vec(&mut r, len);
                let ib = i8_vec(&mut r, len);
                assert_eq!(
                    dot_i8_with(KernelVariant::Scalar, &ia, &ib),
                    dot_i8_with(v, &ia, &ib),
                    "dot_i8 len={len} {}",
                    v.name()
                );
            }
        }
    }

    /// dot4 parity per variant, and the cross-kernel invariant that makes
    /// flat-scan scores layout-independent: dot4(q, rows)[j] is
    /// bit-identical to dot(q, row_j) in the same variant.
    #[test]
    fn prop_dot4_bit_exact_vs_per_row_dot() {
        let variants: Vec<KernelVariant> =
            std::iter::once(KernelVariant::Scalar).chain(simd_variant()).collect();
        let mut r = Rng::new(0xB10C);
        for &dim in &[1usize, 4, 7, 8, 9, 16, 32, 63, 64, 96] {
            let q = f32_vec(&mut r, dim);
            let rows = f32_vec(&mut r, 4 * dim);
            let iq = i8_vec(&mut r, dim);
            let irows = i8_vec(&mut r, 4 * dim);
            for &v in &variants {
                let block = dot4_with(v, &q, &rows, dim);
                let iblock = dot4_i8_with(v, &iq, &irows, dim);
                for j in 0..4 {
                    let row = &rows[j * dim..(j + 1) * dim];
                    assert_eq!(
                        block[j].to_bits(),
                        dot_with(v, &q, row).to_bits(),
                        "dot4 dim={dim} row={j} {}",
                        v.name()
                    );
                    let irow = &irows[j * dim..(j + 1) * dim];
                    assert_eq!(
                        iblock[j],
                        dot_i8_with(v, &iq, irow),
                        "dot4_i8 dim={dim} row={j} {}",
                        v.name()
                    );
                }
                // And across variants: scalar vs v (vacuous when v is
                // Scalar, the bit-exact contract when v is SIMD).
                let sblock = dot4_with(KernelVariant::Scalar, &q, &rows, dim);
                for j in 0..4 {
                    assert_eq!(block[j].to_bits(), sblock[j].to_bits());
                }
            }
        }
    }

    #[test]
    fn active_variant_is_stable_and_named() {
        let v = active_variant();
        assert_eq!(v, active_variant());
        assert!(["scalar", "avx2", "neon"].contains(&v.name()));
    }
}
