//! Exact brute-force vector index over contiguous storage.
//!
//! Vectors live in one `Vec<f32>` (id-parallel), so a full scan is a single
//! sequential sweep — the fastest exact option at the corpus sizes the
//! semantic cache sees (10³–10⁵ entries), and the baseline the IVF index is
//! benchmarked against.
//!
//! Scan layout (the L3 hot path, see `benches/hotpath`):
//! * Cosine rows are stored **pre-normalized** at insert, so the scan is a
//!   pure dot product scaled once by the query's inverse norm.
//! * The scan is **blocked four rows at a time** (`super::dot4`) so the
//!   query stays in registers while rows stream from memory.
//! * An id→slot [`HashMap`] makes [`FlatIndex::remove`] O(1) instead of the
//!   former O(n) `position` scan.
//! * [`FlatIndex::save`]/[`FlatIndex::load`] snapshot the raw id and row
//!   bytes in bulk — load rebuilds the index without re-inserting (and,
//!   because cosine rows are already normalized, without re-computing
//!   norms) and validates the byte length against the declared header.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::{normalize_in_place, Hit, Metric, VectorIndex};

/// Snapshot magic + format version. Bumped from the seed's headerless v1
/// when rows became pre-normalized (a v1 reader would mis-score them).
const SNAPSHOT_MAGIC: &[u8; 4] = b"LBV2";
/// magic(4) + dim(u32) + metric(u8) + count(u64)
const SNAPSHOT_HEADER: usize = 4 + 4 + 1 + 8;

#[derive(Debug)]
pub struct FlatIndex {
    dim: usize,
    metric: Metric,
    ids: Vec<u64>,
    /// Row-major vectors; cosine rows are unit-normalized at insert.
    data: Vec<f32>,
    /// id → row slot, kept in sync by insert/remove.
    slots: HashMap<u64, usize>,
}

impl FlatIndex {
    pub fn new(dim: usize, metric: Metric) -> FlatIndex {
        FlatIndex {
            dim,
            metric,
            ids: Vec::new(),
            data: Vec::new(),
            slots: HashMap::new(),
        }
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Whether `id` has a row (O(1) via the id→slot map) — the snapshot
    /// bulk-load uses this to cross-validate key rows against vectors.
    pub fn contains(&self, id: u64) -> bool {
        self.slots.contains_key(&id)
    }

    fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Slot-ordered ids (parallel to [`FlatIndex::rows`]).
    pub(crate) fn ids(&self) -> &[u64] {
        &self.ids
    }

    /// Row-major storage (cosine rows pre-normalized) — the adaptive
    /// tier's migration/export path reads rows in bulk from here.
    pub(crate) fn rows(&self) -> &[f32] {
        &self.data
    }

    /// Visit every `(id, row)` pair in slot order.
    pub(crate) fn for_each_row(&self, mut f: impl FnMut(u64, &[f32])) {
        for (i, &id) in self.ids.iter().enumerate() {
            f(id, self.row(i));
        }
    }

    /// Binary snapshot: `LBV2 [dim u32][metric u8][count u64][ids..][rows..]`
    /// with ids and rows written as contiguous little-endian byte runs.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut out: Vec<u8> =
            Vec::with_capacity(SNAPSHOT_HEADER + self.ids.len() * 8 + self.data.len() * 4);
        out.extend_from_slice(SNAPSHOT_MAGIC);
        out.extend((self.dim as u32).to_le_bytes());
        out.push(match self.metric {
            Metric::Cosine => 0,
            Metric::Dot => 1,
            Metric::L2 => 2,
        });
        out.extend((self.ids.len() as u64).to_le_bytes());
        for id in &self.ids {
            out.extend_from_slice(&id.to_le_bytes());
        }
        for v in &self.data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        // write + fsync: snapshots participate in the persist layer's
        // crash-safety story, so a committed snapshot directory must not
        // hold a page-cache-only vecdb.bin.
        let mut f = std::fs::File::create(path)?;
        std::io::Write::write_all(&mut f, &out)?;
        f.sync_all()?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<FlatIndex> {
        let bytes = std::fs::read(path)?;
        Self::from_snapshot_bytes(&bytes)
    }

    pub(crate) fn from_snapshot_bytes(bytes: &[u8]) -> Result<FlatIndex> {
        if bytes.len() < SNAPSHOT_HEADER {
            bail!(
                "truncated vecdb snapshot: {} bytes, header is {SNAPSHOT_HEADER}",
                bytes.len()
            );
        }
        if &bytes[0..4] != SNAPSHOT_MAGIC {
            bail!("unsupported vecdb snapshot (bad magic; expected LBV2)");
        }
        let dim = u32::from_le_bytes(bytes[4..8].try_into()?) as usize;
        let metric = match bytes[8] {
            0 => Metric::Cosine,
            1 => Metric::Dot,
            2 => Metric::L2,
            m => bail!("bad metric tag {m}"),
        };
        let count = u64::from_le_bytes(bytes[9..17].try_into()?) as usize;
        // Validate the declared geometry against the actual byte length
        // before slicing: reject both short data and trailing garbage.
        let want = count
            .checked_mul(8)
            .and_then(|ids| count.checked_mul(dim)?.checked_mul(4).map(|d| (ids, d)))
            .and_then(|(ids, d)| SNAPSHOT_HEADER.checked_add(ids)?.checked_add(d))
            .ok_or_else(|| {
                anyhow::anyhow!("vecdb snapshot header overflows: count={count} dim={dim}")
            })?;
        if bytes.len() != want {
            bail!(
                "corrupt vecdb snapshot: {} bytes for count={count} dim={dim} (expected {want})",
                bytes.len()
            );
        }
        let ids_end = SNAPSHOT_HEADER + count * 8;
        let mut ids = Vec::with_capacity(count);
        for c in bytes[SNAPSHOT_HEADER..ids_end].chunks_exact(8) {
            ids.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let mut data = Vec::with_capacity(count * dim);
        for c in bytes[ids_end..].chunks_exact(4) {
            data.push(f32::from_le_bytes(c.try_into().unwrap()));
        }
        let slots = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        Ok(FlatIndex {
            dim,
            metric,
            ids,
            data,
            slots,
        })
    }

    /// Insert a row that is **already in stored form** (pre-normalized for
    /// cosine), verbatim — no re-normalization. Replication applies peer
    /// rows through this so replicas stay bit-identical: re-normalizing an
    /// already-unit row is not an f32 no-op.
    pub(crate) fn insert_stored(&mut self, id: u64, row: &[f32]) -> Result<()> {
        if row.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", row.len(), self.dim);
        }
        let slot = self.ids.len();
        self.ids.push(id);
        self.data.extend_from_slice(row);
        self.slots.insert(id, slot);
        Ok(())
    }
}

impl VectorIndex for FlatIndex {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.ids.len()
    }

    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()> {
        if vector.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", vector.len(), self.dim);
        }
        let slot = self.ids.len();
        self.ids.push(id);
        self.data.extend_from_slice(vector);
        if self.metric == Metric::Cosine {
            // Pre-normalize so the scan is a pure dot product.
            let start = slot * self.dim;
            normalize_in_place(&mut self.data[start..start + self.dim]);
        }
        self.slots.insert(id, slot);
        Ok(())
    }

    fn remove(&mut self, id: u64) -> bool {
        let Some(i) = self.slots.remove(&id) else {
            return false;
        };
        let last = self.ids.len() - 1;
        self.ids.swap(i, last);
        self.ids.pop();
        // swap_remove the row.
        if i != last {
            let (head, tail) = self.data.split_at_mut(last * self.dim);
            head[i * self.dim..(i + 1) * self.dim].copy_from_slice(&tail[..self.dim]);
            self.slots.insert(self.ids[i], i);
        }
        self.data.truncate(last * self.dim);
        true
    }

    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit> {
        if k == 0 || self.ids.is_empty() {
            return Vec::new();
        }
        let mut top: Vec<Hit> = Vec::with_capacity(k + 1);
        match self.metric {
            Metric::Cosine => {
                // Rows are unit-normalized, so score = dot(q, row) / |q|.
                let qn = super::dot(query, query).sqrt();
                let q_inv = if qn == 0.0 { 0.0 } else { 1.0 / qn };
                super::scan_cosine_rows(
                    &mut top, query, q_inv, &self.ids, &self.data, self.dim, k, min_score,
                );
            }
            _ => {
                super::scan_metric_rows(
                    &mut top,
                    self.metric,
                    query,
                    &self.ids,
                    &self.data,
                    self.dim,
                    k,
                    min_score,
                );
            }
        }
        top
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn rand_vec(r: &mut Rng, dim: usize) -> Vec<f32> {
        (0..dim).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn exact_nearest_neighbor() {
        let mut idx = FlatIndex::new(4, Metric::Cosine);
        idx.insert(1, &[1.0, 0.0, 0.0, 0.0]).unwrap();
        idx.insert(2, &[0.0, 1.0, 0.0, 0.0]).unwrap();
        idx.insert(3, &[0.7, 0.7, 0.0, 0.0]).unwrap();
        let hits = idx.search(&[1.0, 0.1, 0.0, 0.0], 2, 0.0);
        assert_eq!(hits[0].id, 1);
        assert_eq!(hits[1].id, 3);
    }

    #[test]
    fn threshold_filters() {
        let mut idx = FlatIndex::new(2, Metric::Cosine);
        idx.insert(1, &[1.0, 0.0]).unwrap();
        idx.insert(2, &[0.0, 1.0]).unwrap();
        let hits = idx.search(&[1.0, 0.0], 10, 0.9);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 1);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut idx = FlatIndex::new(4, Metric::Dot);
        assert!(idx.insert(1, &[1.0]).is_err());
    }

    #[test]
    fn remove_swaps_correctly() {
        let mut idx = FlatIndex::new(2, Metric::Dot);
        for i in 0..5u64 {
            idx.insert(i, &[i as f32, 0.0]).unwrap();
        }
        assert!(idx.remove(2));
        assert!(!idx.remove(2));
        assert_eq!(idx.len(), 4);
        let hits = idx.search(&[1.0, 0.0], 10, f32::MIN);
        assert!(hits.iter().all(|h| h.id != 2));
        assert_eq!(hits.len(), 4);
    }

    #[test]
    fn remove_then_insert_keeps_slots_consistent() {
        let mut r = Rng::new(21);
        let mut idx = FlatIndex::new(4, Metric::Cosine);
        for i in 0..40u64 {
            idx.insert(i, &rand_vec(&mut r, 4)).unwrap();
        }
        for i in (0..40u64).step_by(3) {
            assert!(idx.remove(i));
        }
        for i in 100..110u64 {
            idx.insert(i, &rand_vec(&mut r, 4)).unwrap();
        }
        // Every surviving id is findable and removable exactly once.
        let q = rand_vec(&mut r, 4);
        let hits = idx.search(&q, idx.len(), f32::MIN);
        assert_eq!(hits.len(), idx.len());
        for h in &hits {
            assert!(idx.remove(h.id));
        }
        assert_eq!(idx.len(), 0);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut r = Rng::new(1);
        let mut idx = FlatIndex::new(8, Metric::Cosine);
        for i in 0..50u64 {
            idx.insert(i, &rand_vec(&mut r, 8)).unwrap();
        }
        let dir = std::env::temp_dir().join("llmbridge_vecdb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flat.bin");
        idx.save(&path).unwrap();
        let back = FlatIndex::load(&path).unwrap();
        assert_eq!(back.len(), 50);
        let q = rand_vec(&mut r, 8);
        let a = idx.search(&q, 5, f32::MIN);
        let b = back.search(&q, 5, f32::MIN);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert!((x.score - y.score).abs() < 1e-6);
        }
        // Loaded index stays mutable: remove works off the rebuilt slot map.
        let mut back = back;
        assert!(back.remove(a[0].id));
        assert!(!back.remove(a[0].id));
        assert_eq!(back.len(), 49);
    }

    #[test]
    fn load_rejects_corrupt_snapshots() {
        let mut r = Rng::new(2);
        let mut idx = FlatIndex::new(8, Metric::Cosine);
        for i in 0..10u64 {
            idx.insert(i, &rand_vec(&mut r, 8)).unwrap();
        }
        let dir = std::env::temp_dir().join("llmbridge_vecdb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flat_corrupt.bin");
        idx.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // Short data: truncated mid-row.
        let truncated = &good[..good.len() - 5];
        let err = FlatIndex::from_snapshot_bytes(truncated).unwrap_err();
        assert!(err.to_string().contains("corrupt vecdb snapshot"), "{err}");

        // Trailing garbage after the declared payload.
        let mut trailing = good.clone();
        trailing.extend_from_slice(&[0xAB, 0xCD]);
        let err = FlatIndex::from_snapshot_bytes(&trailing).unwrap_err();
        assert!(err.to_string().contains("corrupt vecdb snapshot"), "{err}");

        // Wrong magic (e.g. a pre-normalization v1 snapshot).
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let err = FlatIndex::from_snapshot_bytes(&bad_magic).unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");

        // Shorter than the header.
        let err = FlatIndex::from_snapshot_bytes(&good[..6]).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn prop_topk_matches_full_sort() {
        forall(
            17,
            30,
            |r| {
                let n = 1 + r.below(200);
                let mut idx = FlatIndex::new(8, Metric::Cosine);
                let mut vecs = Vec::new();
                for i in 0..n {
                    let v = rand_vec(r, 8);
                    idx.insert(i as u64, &v).unwrap();
                    vecs.push(v);
                }
                let q = rand_vec(r, 8);
                (idx, vecs, q)
            },
            |(idx, vecs, q)| {
                let k = 5;
                let hits = idx.search(q, k, f32::MIN);
                // Oracle: full sort by score.
                let mut all: Vec<(u64, f32)> = vecs
                    .iter()
                    .enumerate()
                    .map(|(i, v)| (i as u64, Metric::Cosine.score(q, v)))
                    .collect();
                all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                all.truncate(k);
                hits.len() == all.len().min(k)
                    && hits
                        .iter()
                        .zip(&all)
                        .all(|(h, (id, s))| h.id == *id && (h.score - s).abs() < 1e-5)
            },
        );
    }

    /// The normalized blocked scan must agree with the seed's scalar path
    /// (cosine recomputed from raw vectors per row) on ids and scores.
    #[test]
    fn prop_normalized_scan_matches_scalar_seed_path() {
        forall(
            31,
            20,
            |r| {
                let n = 4 + r.below(300);
                let dim = 64;
                let mut idx = FlatIndex::new(dim, Metric::Cosine);
                let mut vecs = Vec::new();
                for i in 0..n {
                    let v = rand_vec(r, dim);
                    idx.insert(i as u64, &v).unwrap();
                    vecs.push(v);
                }
                let q = rand_vec(r, dim);
                (idx, vecs, q)
            },
            |(idx, vecs, q)| {
                let k = 4;
                let hits = idx.search(q, k, f32::MIN);
                // Seed scalar path: per-row Metric::Cosine.score over the
                // raw (un-normalized) vectors, full sort, truncate.
                let mut all: Vec<(u64, f32)> = vecs
                    .iter()
                    .enumerate()
                    .map(|(i, v)| (i as u64, Metric::Cosine.score(q, v)))
                    .collect();
                all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                all.truncate(k);
                hits.len() == all.len()
                    && hits
                        .iter()
                        .zip(&all)
                        .all(|(h, (id, s))| h.id == *id && (h.score - s).abs() < 1e-5)
            },
        );
    }
}
