//! Exact brute-force vector index over contiguous storage.
//!
//! Vectors live in one `Vec<f32>` (id-parallel), so a full scan is a single
//! sequential sweep — the fastest exact option at the corpus sizes the
//! semantic cache sees (10³–10⁵ entries), and the baseline the IVF index is
//! benchmarked against.

use anyhow::{bail, Result};

use super::{push_topk, Hit, Metric, VectorIndex};

#[derive(Debug)]
pub struct FlatIndex {
    dim: usize,
    metric: Metric,
    ids: Vec<u64>,
    data: Vec<f32>,
    /// Cached inverse norms for cosine (recomputed on insert).
    inv_norms: Vec<f32>,
}

impl FlatIndex {
    pub fn new(dim: usize, metric: Metric) -> FlatIndex {
        FlatIndex {
            dim,
            metric,
            ids: Vec::new(),
            data: Vec::new(),
            inv_norms: Vec::new(),
        }
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Binary snapshot: [dim u32][metric u8][count u64][ids..][data..].
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut out: Vec<u8> = Vec::with_capacity(16 + self.data.len() * 4);
        out.extend((self.dim as u32).to_le_bytes());
        out.push(match self.metric {
            Metric::Cosine => 0,
            Metric::Dot => 1,
            Metric::L2 => 2,
        });
        out.extend((self.ids.len() as u64).to_le_bytes());
        for id in &self.ids {
            out.extend(id.to_le_bytes());
        }
        for v in &self.data {
            out.extend(v.to_le_bytes());
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<FlatIndex> {
        let bytes = std::fs::read(path)?;
        if bytes.len() < 13 {
            bail!("truncated vecdb snapshot");
        }
        let dim = u32::from_le_bytes(bytes[0..4].try_into()?) as usize;
        let metric = match bytes[4] {
            0 => Metric::Cosine,
            1 => Metric::Dot,
            2 => Metric::L2,
            m => bail!("bad metric tag {m}"),
        };
        let count = u64::from_le_bytes(bytes[5..13].try_into()?) as usize;
        let mut idx = FlatIndex::new(dim, metric);
        let mut off = 13;
        let mut ids = Vec::with_capacity(count);
        for _ in 0..count {
            ids.push(u64::from_le_bytes(bytes[off..off + 8].try_into()?));
            off += 8;
        }
        for i in 0..count {
            let mut v = Vec::with_capacity(dim);
            for _ in 0..dim {
                v.push(f32::from_le_bytes(bytes[off..off + 4].try_into()?));
                off += 4;
            }
            idx.insert(ids[i], &v)?;
        }
        Ok(idx)
    }
}

impl VectorIndex for FlatIndex {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.ids.len()
    }

    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()> {
        if vector.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", vector.len(), self.dim);
        }
        self.ids.push(id);
        self.data.extend_from_slice(vector);
        let n = super::dot(vector, vector).sqrt();
        self.inv_norms.push(if n == 0.0 { 0.0 } else { 1.0 / n });
        Ok(())
    }

    fn remove(&mut self, id: u64) -> bool {
        if let Some(i) = self.ids.iter().position(|&x| x == id) {
            let last = self.ids.len() - 1;
            self.ids.swap(i, last);
            self.ids.pop();
            self.inv_norms.swap(i, last);
            self.inv_norms.pop();
            // swap_remove the row.
            if i != last {
                let (head, tail) = self.data.split_at_mut(last * self.dim);
                head[i * self.dim..(i + 1) * self.dim]
                    .copy_from_slice(&tail[..self.dim]);
            }
            self.data.truncate(last * self.dim);
            true
        } else {
            false
        }
    }

    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit> {
        if k == 0 || self.ids.is_empty() {
            return Vec::new();
        }
        let mut top: Vec<Hit> = Vec::with_capacity(k + 1);
        match self.metric {
            Metric::Cosine => {
                let qn = super::dot(query, query).sqrt();
                let q_inv = if qn == 0.0 { 0.0 } else { 1.0 / qn };
                for i in 0..self.ids.len() {
                    let s = super::dot(query, self.row(i)) * q_inv * self.inv_norms[i];
                    if s >= min_score {
                        push_topk(&mut top, Hit { id: self.ids[i], score: s }, k);
                    }
                }
            }
            _ => {
                for i in 0..self.ids.len() {
                    let s = self.metric.score(query, self.row(i));
                    if s >= min_score {
                        push_topk(&mut top, Hit { id: self.ids[i], score: s }, k);
                    }
                }
            }
        }
        top
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn rand_vec(r: &mut Rng, dim: usize) -> Vec<f32> {
        (0..dim).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn exact_nearest_neighbor() {
        let mut idx = FlatIndex::new(4, Metric::Cosine);
        idx.insert(1, &[1.0, 0.0, 0.0, 0.0]).unwrap();
        idx.insert(2, &[0.0, 1.0, 0.0, 0.0]).unwrap();
        idx.insert(3, &[0.7, 0.7, 0.0, 0.0]).unwrap();
        let hits = idx.search(&[1.0, 0.1, 0.0, 0.0], 2, 0.0);
        assert_eq!(hits[0].id, 1);
        assert_eq!(hits[1].id, 3);
    }

    #[test]
    fn threshold_filters() {
        let mut idx = FlatIndex::new(2, Metric::Cosine);
        idx.insert(1, &[1.0, 0.0]).unwrap();
        idx.insert(2, &[0.0, 1.0]).unwrap();
        let hits = idx.search(&[1.0, 0.0], 10, 0.9);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].id, 1);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let mut idx = FlatIndex::new(4, Metric::Dot);
        assert!(idx.insert(1, &[1.0]).is_err());
    }

    #[test]
    fn remove_swaps_correctly() {
        let mut idx = FlatIndex::new(2, Metric::Dot);
        for i in 0..5u64 {
            idx.insert(i, &[i as f32, 0.0]).unwrap();
        }
        assert!(idx.remove(2));
        assert!(!idx.remove(2));
        assert_eq!(idx.len(), 4);
        let hits = idx.search(&[1.0, 0.0], 10, f32::MIN);
        assert!(hits.iter().all(|h| h.id != 2));
        assert_eq!(hits.len(), 4);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut r = Rng::new(1);
        let mut idx = FlatIndex::new(8, Metric::Cosine);
        for i in 0..50u64 {
            idx.insert(i, &rand_vec(&mut r, 8)).unwrap();
        }
        let dir = std::env::temp_dir().join("llmbridge_vecdb_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flat.bin");
        idx.save(&path).unwrap();
        let back = FlatIndex::load(&path).unwrap();
        assert_eq!(back.len(), 50);
        let q = rand_vec(&mut r, 8);
        let a = idx.search(&q, 5, f32::MIN);
        let b = back.search(&q, 5, f32::MIN);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert!((x.score - y.score).abs() < 1e-6);
        }
    }

    #[test]
    fn prop_topk_matches_full_sort() {
        forall(
            17,
            30,
            |r| {
                let n = 1 + r.below(200);
                let mut idx = FlatIndex::new(8, Metric::Cosine);
                let mut vecs = Vec::new();
                for i in 0..n {
                    let v = rand_vec(r, 8);
                    idx.insert(i as u64, &v).unwrap();
                    vecs.push(v);
                }
                let q = rand_vec(r, 8);
                (idx, vecs, q)
            },
            |(idx, vecs, q)| {
                let k = 5;
                let hits = idx.search(q, k, f32::MIN);
                // Oracle: full sort by score.
                let mut all: Vec<(u64, f32)> = vecs
                    .iter()
                    .enumerate()
                    .map(|(i, v)| (i as u64, Metric::Cosine.score(q, v)))
                    .collect();
                all.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
                all.truncate(k);
                hits.len() == all.len().min(k)
                    && hits
                        .iter()
                        .zip(&all)
                        .all(|(h, (id, s))| h.id == *id && (h.score - s).abs() < 1e-5)
            },
        );
    }
}
