//! Quantized IVF tier: posting-list rows stored as i8 codes with one f32
//! scale per row — the million-row memory tier of
//! [`super::adaptive::AdaptiveIndex`].
//!
//! ## Quantization (per-row, symmetric)
//!
//! A stored row `r` becomes `codes[i] = round(r[i] * 127 / max|r|)` with
//! `scale = max|r| / 127`, so `codes[i] * scale ≈ r[i]` with error at most
//! `scale / 2` per coordinate. The vector region shrinks from `dim * 4`
//! bytes/row to `dim + 4` — 3.76x at dim 64 (the cache's embedding dim).
//! The element at `max|r|` always quantizes to ±127, which makes the
//! mapping idempotent on dequantized rows: a retrain that exports
//! dequantized rows and re-quantizes them reproduces the same codes.
//!
//! ## Search (coarse i8 scan + f32 rescore)
//!
//! A query is quantized once, probed cells are scanned with the blocked
//! [`kernel::dot4_i8`] kernel (`approx = i32dot · q_scale · row_scale`),
//! and the top `4·k` survivors — kept **unthresholded**, since the coarse
//! score is approximate — are rescored as `dot(query, dequantize(row))`
//! with `min_score` applied only there. Recall@4 against the exact flat
//! scan is gated ≥ 0.95 by the adaptive-tier property tests.
//!
//! ## Cold boot (per-cell copy-on-write codes)
//!
//! The LBV4 snapshot loader hands cells *views into an mmap* instead of
//! owned buffers: restore returns before any code byte is read, queries
//! fault pages in on demand, and the first **mutation** of a cell
//! materializes only that cell (`CodeStore`) — a WAL-tail replay after
//! restore touches a handful of cells and keeps the rest lazy.

use std::collections::HashMap;
#[cfg(unix)]
use std::sync::Arc;

use anyhow::{bail, Result};

use super::ivf::{nearest_cells, nearest_centroid};
use super::kernel;
use super::{dot, normalize_in_place, push_topk, Hit, Metric, VectorIndex};
#[cfg(unix)]
use crate::util::mmap::MmapRegion;

/// Quantize one row to i8 codes + per-row scale. Zero/degenerate rows
/// (including non-finite maxima) become all-zero codes with scale 0.
pub(crate) fn quantize_row(row: &[f32]) -> (Vec<i8>, f32) {
    let mut max_abs = 0.0f32;
    for &x in row {
        max_abs = max_abs.max(x.abs());
    }
    if max_abs == 0.0 || !max_abs.is_finite() {
        return (vec![0i8; row.len()], 0.0);
    }
    let scale = max_abs / 127.0;
    let inv = 127.0 / max_abs;
    let codes = row
        .iter()
        .map(|&x| (x * inv).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (codes, scale)
}

/// View i8 codes as the raw bytes the snapshot writer stores.
pub(crate) fn codes_as_bytes(codes: &[i8]) -> &[u8] {
    // Safety: i8 and u8 share size, alignment, and validity.
    unsafe { std::slice::from_raw_parts(codes.as_ptr() as *const u8, codes.len()) }
}

/// Reconstruct `codes[i] * scale` into `out`.
pub(crate) fn dequantize_row(codes: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = c as f32 * scale;
    }
}

/// One cell's code bytes: owned, or a lazy view into the LBV4 mmap that
/// is materialized (copy-on-write) the first time the cell mutates.
#[derive(Debug)]
enum CodeStore {
    Owned(Vec<i8>),
    #[cfg(unix)]
    Mapped {
        map: Arc<MmapRegion>,
        /// Byte offset of this cell's first code within the map.
        offset: usize,
        /// Code count (= rows · dim).
        len: usize,
    },
}

impl CodeStore {
    fn as_codes(&self) -> &[i8] {
        match self {
            CodeStore::Owned(v) => v,
            #[cfg(unix)]
            CodeStore::Mapped { map, offset, len } => {
                let bytes = &map.as_bytes()[*offset..*offset + *len];
                // Safety: i8 and u8 share size, alignment, and validity.
                unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const i8, bytes.len()) }
            }
        }
    }

    fn is_mapped(&self) -> bool {
        !matches!(self, CodeStore::Owned(_))
    }

    /// Copy-on-write: materialize (if mapped) and return the owned buffer.
    fn make_owned(&mut self) -> &mut Vec<i8> {
        if self.is_mapped() {
            *self = CodeStore::Owned(self.as_codes().to_vec());
        }
        match self {
            CodeStore::Owned(v) => v,
            #[cfg(unix)]
            CodeStore::Mapped { .. } => unreachable!("materialized above"),
        }
    }
}

/// Where [`QuantIvfIndex::from_grouped_parts`] takes its code bytes from.
pub(crate) enum CodesSource<'a> {
    /// A contiguous `count * dim` byte region, copied into owned cells
    /// (the from-bytes loader and the non-unix fallback).
    Eager(&'a [u8]),
    /// A whole-file map; cells become lazy views at `codes_off`.
    #[cfg(unix)]
    Mapped { map: Arc<MmapRegion>, codes_off: usize },
}

/// IVF index over i8-quantized rows. Always trained (it is only ever
/// built from a trained plan or a snapshot); inserts after construction
/// land in the nearest cell like the f32 IVF tier.
#[derive(Debug)]
pub struct QuantIvfIndex {
    dim: usize,
    metric: Metric,
    nlist: usize,
    pub nprobe: usize,
    /// nlist x dim, f32 — centroids stay unquantized (they are nlist·dim
    /// floats, negligible next to the corpus).
    centroids: Vec<f32>,
    /// Per-cell ids, parallel to scales/codes slots.
    list_ids: Vec<Vec<u64>>,
    /// Per-cell per-row dequantization scales.
    list_scales: Vec<Vec<f32>>,
    /// Per-cell contiguous row-major i8 codes (owned or mmap views).
    list_codes: Vec<CodeStore>,
    /// id → (cell, slot); O(1) remove/contains like the other tiers.
    locs: HashMap<u64, (u32, u32)>,
}

impl QuantIvfIndex {
    /// Build from a trained plan: f32 rows (already in stored form — cosine
    /// rows pre-normalized) are quantized on the way into their assigned
    /// cells. Validation mirrors [`super::ivf::IvfIndex::from_trained_parts`].
    pub fn from_trained_parts(
        dim: usize,
        metric: Metric,
        nprobe: usize,
        centroids: Vec<f32>,
        ids: Vec<u64>,
        rows: &[f32],
        assignments: &[u32],
    ) -> Result<QuantIvfIndex> {
        if dim == 0 {
            bail!("quant snapshot: dim must be positive");
        }
        if centroids.is_empty() || centroids.len() % dim != 0 {
            bail!(
                "quant snapshot: {} centroid floats is not a positive multiple of dim {dim}",
                centroids.len()
            );
        }
        if rows.len() != ids.len() * dim {
            bail!(
                "quant snapshot: {} row floats for {} ids at dim {dim}",
                rows.len(),
                ids.len()
            );
        }
        if assignments.len() != ids.len() {
            bail!(
                "quant snapshot: {} assignments for {} ids",
                assignments.len(),
                ids.len()
            );
        }
        let nlist = centroids.len() / dim;
        let mut idx = QuantIvfIndex {
            dim,
            metric,
            nlist,
            nprobe: nprobe.max(1),
            centroids,
            list_ids: vec![Vec::new(); nlist],
            list_scales: vec![Vec::new(); nlist],
            list_codes: (0..nlist).map(|_| CodeStore::Owned(Vec::new())).collect(),
            locs: HashMap::with_capacity(ids.len()),
        };
        for (i, (&id, &cell)) in ids.iter().zip(assignments).enumerate() {
            let c = cell as usize;
            if c >= nlist {
                bail!("quant snapshot: row {i} assigned to cell {c} of {nlist}");
            }
            let (codes, scale) = quantize_row(&rows[i * dim..(i + 1) * dim]);
            let slot = idx.list_ids[c].len() as u32;
            idx.list_ids[c].push(id);
            idx.list_scales[c].push(scale);
            idx.list_codes[c].make_owned().extend_from_slice(&codes);
            if idx.locs.insert(id, (cell, slot)).is_some() {
                bail!("quant snapshot: duplicate id {id}");
            }
        }
        Ok(idx)
    }

    /// Build from already-quantized, **cell-grouped** parts — the LBV4
    /// restore path. `assignments` must be non-decreasing (the writer
    /// groups cells), which is what lets mapped cells be contiguous views;
    /// a violation means the snapshot is corrupt.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_grouped_parts(
        dim: usize,
        metric: Metric,
        nprobe: usize,
        centroids: Vec<f32>,
        ids: Vec<u64>,
        scales: Vec<f32>,
        assignments: &[u32],
        codes: CodesSource<'_>,
    ) -> Result<QuantIvfIndex> {
        if dim == 0 {
            bail!("quant snapshot: dim must be positive");
        }
        if centroids.is_empty() || centroids.len() % dim != 0 {
            bail!(
                "quant snapshot: {} centroid floats is not a positive multiple of dim {dim}",
                centroids.len()
            );
        }
        let nlist = centroids.len() / dim;
        let count = ids.len();
        if scales.len() != count || assignments.len() != count {
            bail!(
                "quant snapshot: {} scales / {} assignments for {count} ids",
                scales.len(),
                assignments.len()
            );
        }
        let codes_len = match &codes {
            CodesSource::Eager(bytes) => bytes.len(),
            #[cfg(unix)]
            CodesSource::Mapped { map, codes_off } => map.len().saturating_sub(*codes_off),
        };
        if codes_len != count * dim {
            bail!(
                "quant snapshot: {codes_len} code bytes for {count} rows at dim {dim}",
            );
        }
        let mut idx = QuantIvfIndex {
            dim,
            metric,
            nlist,
            nprobe: nprobe.max(1),
            centroids,
            list_ids: Vec::with_capacity(nlist),
            list_scales: Vec::with_capacity(nlist),
            list_codes: Vec::with_capacity(nlist),
            locs: HashMap::with_capacity(count),
        };
        // Cell boundaries from the grouped (non-decreasing) assignments.
        let mut starts = vec![count; nlist + 1];
        let mut prev: i64 = -1;
        for (i, &cell) in assignments.iter().enumerate() {
            let c = cell as usize;
            if c >= nlist {
                bail!("quant snapshot: row {i} assigned to cell {c} of {nlist}");
            }
            if (c as i64) < prev {
                bail!("quant snapshot: assignments not cell-grouped at row {i}");
            }
            if c as i64 > prev {
                // Mark the start of every cell in (prev, c].
                for s in &mut starts[(prev + 1) as usize..=c] {
                    *s = i;
                }
                prev = c as i64;
            }
        }
        for s in &mut starts[(prev + 1) as usize..] {
            *s = count;
        }
        for c in 0..nlist {
            let (start, end) = (starts[c], starts[c + 1]);
            for (slot, &id) in ids[start..end].iter().enumerate() {
                if idx
                    .locs
                    .insert(id, (c as u32, slot as u32))
                    .is_some()
                {
                    bail!("quant snapshot: duplicate id {id}");
                }
            }
            idx.list_ids.push(ids[start..end].to_vec());
            idx.list_scales.push(scales[start..end].to_vec());
            idx.list_codes.push(match &codes {
                CodesSource::Eager(bytes) => {
                    let region = &bytes[start * dim..end * dim];
                    // Safety: i8 and u8 share size, alignment, validity.
                    let as_i8 = unsafe {
                        std::slice::from_raw_parts(region.as_ptr() as *const i8, region.len())
                    };
                    CodeStore::Owned(as_i8.to_vec())
                }
                #[cfg(unix)]
                CodesSource::Mapped { map, codes_off } => CodeStore::Mapped {
                    map: Arc::clone(map),
                    offset: codes_off + start * dim,
                    len: (end - start) * dim,
                },
            });
        }
        Ok(idx)
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    pub fn nlist(&self) -> usize {
        self.nlist
    }

    pub fn centroids(&self) -> &[f32] {
        &self.centroids
    }

    pub fn contains(&self, id: u64) -> bool {
        self.locs.contains_key(&id)
    }

    /// Logical bytes of the scan region: i8 codes + one f32 scale per row
    /// (vs `dim * 4` for an f32 tier).
    pub fn vector_bytes(&self) -> usize {
        self.locs.len() * (self.dim + 4)
    }

    /// Cells still backed by lazy mmap views (0 once fully materialized,
    /// or on an index that was never restored from LBV4).
    pub fn mapped_cells(&self) -> usize {
        self.list_codes.iter().filter(|c| c.is_mapped()).count()
    }

    /// Insert a row already in stored form (cosine rows pre-normalized) —
    /// quantizes on the way in. The migration/reconcile path.
    pub(crate) fn insert_stored(&mut self, id: u64, v: &[f32]) -> Result<()> {
        if v.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", v.len(), self.dim);
        }
        let c = nearest_centroid(self.metric, &self.centroids, self.dim, v);
        let (codes, scale) = quantize_row(v);
        let slot = self.list_ids[c].len() as u32;
        self.list_ids[c].push(id);
        self.list_scales[c].push(scale);
        self.list_codes[c].make_owned().extend_from_slice(&codes);
        self.locs.insert(id, (c as u32, slot));
        Ok(())
    }

    /// Visit every `(id, dequantized row)` pair — the export shape the
    /// rebuild/reconcile machinery shares across tiers. Rows are
    /// reconstructed into a scratch buffer (`codes[i] * scale`).
    pub(crate) fn for_each_row(&self, mut f: impl FnMut(u64, &[f32])) {
        let mut row = vec![0.0f32; self.dim];
        for c in 0..self.nlist {
            let codes = self.list_codes[c].as_codes();
            for (i, &id) in self.list_ids[c].iter().enumerate() {
                dequantize_row(
                    &codes[i * self.dim..(i + 1) * self.dim],
                    self.list_scales[c][i],
                    &mut row,
                );
                f(id, &row);
            }
        }
    }

    /// Slot-ordered `(ids, scales, assignments, codes)` grouped by cell —
    /// the LBV4 payload. Codes are cell-contiguous, which is what lets the
    /// mmap loader adopt them in place.
    pub(crate) fn export_quantized_parts(&self) -> (Vec<u64>, Vec<f32>, Vec<u32>, Vec<i8>) {
        let n = self.locs.len();
        let mut ids = Vec::with_capacity(n);
        let mut scales = Vec::with_capacity(n);
        let mut assignments = Vec::with_capacity(n);
        let mut codes = Vec::with_capacity(n * self.dim);
        for c in 0..self.nlist {
            ids.extend_from_slice(&self.list_ids[c]);
            scales.extend_from_slice(&self.list_scales[c]);
            assignments.extend(std::iter::repeat(c as u32).take(self.list_ids[c].len()));
            codes.extend_from_slice(self.list_codes[c].as_codes());
        }
        (ids, scales, assignments, codes)
    }

    /// Top-k over the `probes` nearest cells — same widening knob as the
    /// f32 IVF tier. Cosine/Dot run the coarse-i8 + f32-rescore pipeline;
    /// other metrics score dequantized rows directly.
    pub fn search_probes(
        &self,
        query: &[f32],
        k: usize,
        min_score: f32,
        probes: usize,
    ) -> Vec<Hit> {
        let mut top: Vec<Hit> = Vec::with_capacity(k + 1);
        if k == 0 || self.locs.is_empty() {
            return top;
        }
        let probes = probes.max(1);
        match self.metric {
            Metric::Cosine | Metric::Dot => {
                self.search_coarse_rescore(query, k, min_score, probes, &mut top)
            }
            Metric::L2 => self.search_dequantized(query, k, min_score, probes, &mut top),
        }
        top
    }

    fn search_coarse_rescore(
        &self,
        query: &[f32],
        k: usize,
        min_score: f32,
        probes: usize,
        top: &mut Vec<Hit>,
    ) {
        // Stored cosine rows are unit-normalized: score = dot / |q|.
        let q_inv = if self.metric == Metric::Cosine {
            let n = dot(query, query).sqrt();
            if n == 0.0 {
                0.0
            } else {
                1.0 / n
            }
        } else {
            1.0
        };
        let (q_codes, q_scale) = quantize_row(query);
        // Coarse shortlist: top 4·k by approximate score, unthresholded —
        // min_score is in exact-score units and must wait for the rescore.
        let shortlist = k.saturating_mul(4).max(k);
        let mut cand: Vec<Hit> = Vec::with_capacity(shortlist + 1);
        for c in nearest_cells(self.metric, &self.centroids, self.dim, query, probes) {
            let ids = &self.list_ids[c];
            let scales = &self.list_scales[c];
            let codes = self.list_codes[c].as_codes();
            let n = ids.len();
            let blocks = n / 4;
            for b in 0..blocks {
                let i = b * 4;
                let raw = kernel::dot4_i8(
                    &q_codes,
                    &codes[i * self.dim..(i + 4) * self.dim],
                    self.dim,
                );
                for (j, &r) in raw.iter().enumerate() {
                    let approx = r as f32 * q_scale * scales[i + j];
                    push_topk(
                        &mut cand,
                        Hit {
                            id: ids[i + j],
                            score: approx,
                        },
                        shortlist,
                    );
                }
            }
            for i in blocks * 4..n {
                let r = kernel::dot_i8(&q_codes, &codes[i * self.dim..(i + 1) * self.dim]);
                let approx = r as f32 * q_scale * scales[i];
                push_topk(
                    &mut cand,
                    Hit {
                        id: ids[i],
                        score: approx,
                    },
                    shortlist,
                );
            }
        }
        // Rescore survivors in f32 against the dequantized row; apply
        // min_score only on the exact score.
        let mut row = vec![0.0f32; self.dim];
        for h in &cand {
            let (cell, slot) = self.locs[&h.id];
            let (c, i) = (cell as usize, slot as usize);
            let codes = self.list_codes[c].as_codes();
            dequantize_row(
                &codes[i * self.dim..(i + 1) * self.dim],
                self.list_scales[c][i],
                &mut row,
            );
            let s = if self.metric == Metric::Cosine {
                dot(query, &row) * q_inv
            } else {
                dot(query, &row)
            };
            if s >= min_score {
                push_topk(top, Hit { id: h.id, score: s }, k);
            }
        }
    }

    /// Generic-metric fallback (L2): score every probed row against its
    /// dequantized form — correct, without the coarse-i8 speedup.
    fn search_dequantized(
        &self,
        query: &[f32],
        k: usize,
        min_score: f32,
        probes: usize,
        top: &mut Vec<Hit>,
    ) {
        let mut row = vec![0.0f32; self.dim];
        for c in nearest_cells(self.metric, &self.centroids, self.dim, query, probes) {
            let codes = self.list_codes[c].as_codes();
            for (i, &id) in self.list_ids[c].iter().enumerate() {
                dequantize_row(
                    &codes[i * self.dim..(i + 1) * self.dim],
                    self.list_scales[c][i],
                    &mut row,
                );
                let s = self.metric.score(query, &row);
                if s >= min_score {
                    push_topk(top, Hit { id, score: s }, k);
                }
            }
        }
    }
}

impl VectorIndex for QuantIvfIndex {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.locs.len()
    }

    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()> {
        if vector.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", vector.len(), self.dim);
        }
        let mut v = vector.to_vec();
        if self.metric == Metric::Cosine {
            normalize_in_place(&mut v);
        }
        self.insert_stored(id, &v)
    }

    fn remove(&mut self, id: u64) -> bool {
        let Some((cell, slot)) = self.locs.remove(&id) else {
            return false;
        };
        let c = cell as usize;
        let slot = slot as usize;
        let last = self.list_ids[c].len() - 1;
        self.list_ids[c].swap(slot, last);
        self.list_ids[c].pop();
        self.list_scales[c].swap(slot, last);
        self.list_scales[c].pop();
        let dim = self.dim;
        let codes = self.list_codes[c].make_owned();
        if slot != last {
            let (head, tail) = codes.split_at_mut(last * dim);
            head[slot * dim..(slot + 1) * dim].copy_from_slice(&tail[..dim]);
        }
        codes.truncate(last * dim);
        if slot != last {
            let moved = self.list_ids[c][slot];
            self.locs.insert(moved, (cell, slot as u32));
        }
        true
    }

    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit> {
        self.search_probes(query, k, min_score, self.nprobe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::corpus::{balanced_clustered_pairs, clustered_pairs, perturbed};
    use crate::util::rng::Rng;
    use crate::vecdb::flat::FlatIndex;
    use crate::vecdb::ivf::kmeans_centroids;

    fn trained_over(
        data: &[(u64, Vec<f32>)],
        dim: usize,
        nlist: usize,
        nprobe: usize,
    ) -> QuantIvfIndex {
        // Stored form: cosine rows pre-normalized (what the rebuild plan
        // exports).
        let mut rows = Vec::with_capacity(data.len() * dim);
        for (_, v) in data {
            let mut r = v.clone();
            normalize_in_place(&mut r);
            rows.extend_from_slice(&r);
        }
        let mut rng = Rng::new(0x5EED);
        let centroids = kmeans_centroids(&mut rng, Metric::Cosine, &rows, dim, nlist, 4);
        let assignments: Vec<u32> = (0..data.len())
            .map(|i| {
                nearest_centroid(Metric::Cosine, &centroids, dim, &rows[i * dim..(i + 1) * dim])
                    as u32
            })
            .collect();
        let ids: Vec<u64> = data.iter().map(|(id, _)| *id).collect();
        QuantIvfIndex::from_trained_parts(
            dim,
            Metric::Cosine,
            nprobe,
            centroids,
            ids,
            &rows,
            &assignments,
        )
        .unwrap()
    }

    #[test]
    fn quantize_roundtrip_error_bound_and_idempotence() {
        let mut rng = Rng::new(21);
        for _ in 0..50 {
            let row: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
            let (codes, scale) = quantize_row(&row);
            assert!(codes.iter().all(|&c| (-127..=127).contains(&(c as i32))));
            let mut back = vec![0.0f32; 64];
            dequantize_row(&codes, scale, &mut back);
            // Error bound: half a quantization step per coordinate.
            for (x, y) in row.iter().zip(&back) {
                assert!((x - y).abs() <= scale * 0.5 + 1e-6, "err {} step {scale}", x - y);
            }
            // Idempotence: re-quantizing the dequantized row reproduces
            // the codes exactly (scale to 1-ulp tolerance).
            let (codes2, scale2) = quantize_row(&back);
            assert_eq!(codes, codes2);
            assert!((scale - scale2).abs() <= scale * 1e-6);
        }
    }

    #[test]
    fn quantize_zero_and_degenerate_rows() {
        let (codes, scale) = quantize_row(&[0.0; 8]);
        assert_eq!(codes, vec![0i8; 8]);
        assert_eq!(scale, 0.0);
        let (codes, scale) = quantize_row(&[f32::INFINITY, 1.0]);
        assert_eq!(codes, vec![0i8; 2]);
        assert_eq!(scale, 0.0);
    }

    #[test]
    fn bytes_per_row_cut_at_least_3_5x() {
        let dim = 64;
        let data = clustered_pairs(0xB17E, 2000, dim, 16, 8.0, 0.4);
        let q = trained_over(&data, dim, 16, 4);
        let f32_bytes = data.len() * dim * 4;
        let ratio = f32_bytes as f64 / q.vector_bytes() as f64;
        assert!(ratio >= 3.5, "vector-region cut only {ratio:.2}x");
    }

    #[test]
    fn recall_at_4_vs_flat_on_clustered_corpus() {
        // 4 points per cluster: the exact top-4 of a query near a stored
        // point is its whole cluster, separated from everything else by a
        // spread-scale score gap — so a miss means the index lost the
        // neighborhood (bad probe or coarse scan), not that quantization
        // tie-broke near-equal neighbors differently.
        let dim = 64;
        let data = balanced_clustered_pairs(0xACE, 2000, 4, dim, 8.0, 0.4);
        let mut flat = FlatIndex::new(dim, Metric::Cosine);
        for (id, v) in &data {
            flat.insert(*id, v).unwrap();
        }
        let q = trained_over(&data, dim, 64, 8);
        let mut rng = Rng::new(0xFACE);
        let (mut found, mut total) = (0usize, 0usize);
        for _ in 0..50 {
            let (_, base) = &data[rng.below(data.len())];
            let probe = perturbed(&mut rng, base, 0.1);
            let truth: Vec<u64> = flat.search(&probe, 4, f32::MIN).iter().map(|h| h.id).collect();
            let got: Vec<u64> = q.search(&probe, 4, f32::MIN).iter().map(|h| h.id).collect();
            total += truth.len();
            found += truth.iter().filter(|t| got.contains(t)).count();
        }
        let recall = found as f64 / total as f64;
        assert!(recall >= 0.95, "recall@4={recall}");
    }

    #[test]
    fn insert_remove_churn_keeps_locs_consistent() {
        let dim = 16;
        let data = clustered_pairs(0xC4A7, 600, dim, 8, 8.0, 0.4);
        let mut q = trained_over(&data, dim, 8, 8);
        let mut rng = Rng::new(31);
        let mut live: Vec<u64> = data.iter().map(|(id, _)| *id).collect();
        for round in 0..400 {
            if !live.is_empty() && rng.chance(0.5) {
                let pick = rng.below(live.len());
                let id = live.swap_remove(pick);
                assert!(q.remove(id), "round {round}: remove({id})");
                assert!(!q.contains(id));
            } else {
                let id = 10_000 + round as u64;
                let v = data[rng.below(data.len())].1.clone();
                q.insert(id, &v).unwrap();
                live.push(id);
            }
            assert_eq!(q.len(), live.len());
        }
        for id in &live {
            assert!(q.contains(*id));
        }
        // Exhaustive probe sees exactly the live set.
        let got: std::collections::HashSet<u64> = q
            .search_probes(&data[0].1, live.len(), f32::MIN, q.nlist())
            .iter()
            .map(|h| h.id)
            .collect();
        assert_eq!(got.len(), live.len());
    }

    #[test]
    fn grouped_parts_roundtrip_bit_exact() {
        let dim = 32;
        let data = clustered_pairs(0x909, 1200, dim, 12, 8.0, 0.4);
        let q = trained_over(&data, dim, 12, 6);
        let (ids, scales, assignments, codes) = q.export_quantized_parts();
        // i8 → u8 byte view, as the snapshot writer stores it.
        let code_bytes: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
        let back = QuantIvfIndex::from_grouped_parts(
            dim,
            Metric::Cosine,
            q.nprobe,
            q.centroids().to_vec(),
            ids,
            scales,
            &assignments,
            CodesSource::Eager(&code_bytes),
        )
        .unwrap();
        assert_eq!(back.len(), q.len());
        assert_eq!(back.nlist(), q.nlist());
        assert_eq!(back.mapped_cells(), 0);
        let mut rng = Rng::new(23);
        for _ in 0..20 {
            let (_, base) = &data[rng.below(data.len())];
            let probe = perturbed(&mut rng, base, 0.1);
            let a = q.search(&probe, 6, f32::MIN);
            let b = back.search(&probe, 6, f32::MIN);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits(), "score drifted");
            }
        }
    }

    #[test]
    fn grouped_parts_rejects_ungrouped_or_bad_assignments() {
        let dim = 4;
        let centroids = vec![0.0f32; 2 * dim];
        let ids = vec![1u64, 2];
        let scales = vec![0.1f32, 0.1];
        let codes = vec![0u8; 2 * dim];
        let build = |ids: Vec<u64>, assignments: &[u32], code_bytes: &[u8]| {
            QuantIvfIndex::from_grouped_parts(
                dim,
                Metric::Cosine,
                2,
                centroids.clone(),
                ids,
                scales.clone(),
                assignments,
                CodesSource::Eager(code_bytes),
            )
        };
        // Valid grouped baseline.
        assert!(build(ids.clone(), &[0, 1], &codes).is_ok());
        // Not cell-grouped (decreasing).
        assert!(build(ids.clone(), &[1, 0], &codes).is_err());
        // Out-of-range cell.
        assert!(build(ids.clone(), &[0, 2], &codes).is_err());
        // Duplicate id.
        assert!(build(vec![1, 1], &[0, 1], &codes).is_err());
        // Code region size mismatch.
        assert!(build(ids, &[0, 1], &codes[..7]).is_err());
    }

    #[test]
    fn min_score_applies_to_exact_not_coarse_score() {
        let dim = 16;
        let data = balanced_clustered_pairs(0x3C0, 125, 4, dim, 8.0, 0.4);
        let q = trained_over(&data, dim, 8, 8);
        let (_, base) = &data[0];
        let mut probe = base.clone();
        normalize_in_place(&mut probe);
        // With a threshold nothing clears, the result is empty even though
        // coarse candidates existed.
        assert!(q.search_probes(&probe, 4, 2.0, q.nlist()).is_empty());
        // With no threshold, the probe's own cluster (ids 0..4) is the
        // top-4, each rescored well above any cross-cluster score.
        let hits = q.search_probes(&probe, 4, f32::MIN, q.nlist());
        let mut got: Vec<u64> = hits.iter().map(|h| h.id).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert!(hits.iter().all(|h| h.score > 0.9), "{hits:?}");
    }
}
