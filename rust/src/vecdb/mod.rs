//! Vector-database substrate — stand-in for the paper's RDS + vector-search
//! extension (§4). Stores fixed-dimension f32 vectors with u64 ids and
//! answers top-k similarity queries with an optional score threshold.
//!
//! Four index implementations behind [`VectorIndex`]:
//! * [`flat::FlatIndex`] — contiguous brute-force scan (exact).
//! * [`ivf::IvfIndex`] — inverted-file index (k-means coarse quantizer with
//!   `nprobe` cell search): sub-linear scans for large corpora.
//! * [`quant::QuantIvfIndex`] — IVF with i8-quantized posting lists
//!   (per-row scale): ~3.8x smaller vector region for million-row corpora,
//!   coarse-scored with an i8 dot kernel and rescored in f32.
//! * [`adaptive::AdaptiveIndex`] — what the semantic cache actually holds:
//!   bit-exact flat below a row threshold, a trained IVF above it, the
//!   quantized tier above a second threshold, with off-read-path
//!   retraining and an atomic tier swap.
//!
//! All scans run through the runtime-dispatched [`kernel`] layer
//! (AVX2/NEON with a bit-exact scalar fallback).

pub mod adaptive;
pub mod flat;
pub mod ivf;
pub mod kernel;
pub mod quant;

use anyhow::Result;

/// Similarity metric. Scores are "higher is better" for all metrics
/// (L2 is negated distance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Cosine,
    Dot,
    L2,
}

impl Metric {
    #[inline]
    pub fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::Dot => dot(a, b),
            Metric::Cosine => {
                let na = dot(a, a).sqrt();
                let nb = dot(b, b).sqrt();
                if na == 0.0 || nb == 0.0 {
                    0.0
                } else {
                    dot(a, b) / (na * nb)
                }
            }
            Metric::L2 => {
                let mut s = 0.0;
                for i in 0..a.len() {
                    let d = a[i] - b[i];
                    s += d * d;
                }
                -s.sqrt()
            }
        }
    }
}

/// f32 dot product — dispatches to the best [`kernel`] variant for this
/// host (AVX2/NEON, or the bit-exact chunked-scalar fallback).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    kernel::dot(a, b)
}

/// Dot of one query against four consecutive rows of a row-major block —
/// the blocked form of the flat-scan hot loop (one query load serves four
/// rows in the SIMD variants). Each output is bit-identical to
/// `dot(q, row_j)`, so blocked and per-row scans agree to the last bit.
#[inline]
pub(crate) fn dot4(q: &[f32], rows: &[f32], dim: usize) -> [f32; 4] {
    debug_assert_eq!(q.len(), dim);
    debug_assert_eq!(rows.len(), 4 * dim);
    kernel::dot4(q, rows, dim)
}

/// Scale `v` to unit L2 norm in place (zero vectors are left untouched).
/// Cosine indexes store rows pre-normalized so the scan is a pure dot.
#[inline]
pub(crate) fn normalize_in_place(v: &mut [f32]) {
    let n = dot(v, v).sqrt();
    if n > 0.0 {
        let inv = 1.0 / n;
        for x in v {
            *x *= inv;
        }
    }
}

/// A search hit: id + similarity score (higher is better).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f32,
}

pub trait VectorIndex: Send {
    fn dim(&self) -> usize;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()>;
    fn remove(&mut self, id: u64) -> bool;
    /// Top-k by score, filtered to score >= min_score.
    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit>;
}

/// Blocked scan of contiguous row-major storage holding **unit-normalized
/// cosine rows**: score = dot(q, row) * q_inv. Shared by the flat scan and
/// the IVF posting-list scan so both tiers run the identical dot4 kernel.
/// Since dot4 is bit-identical to per-row dot, a row's score does not
/// depend on its slot (dot4-block membership); cross-*variant* equality is
/// the kernel layer's parity contract.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_cosine_rows(
    top: &mut Vec<Hit>,
    query: &[f32],
    q_inv: f32,
    ids: &[u64],
    rows: &[f32],
    dim: usize,
    k: usize,
    min_score: f32,
) {
    let n = ids.len();
    debug_assert_eq!(rows.len(), n * dim);
    let blocks = n / 4;
    for b in 0..blocks {
        let i = b * 4;
        let base = i * dim;
        let scores = dot4(query, &rows[base..base + 4 * dim], dim);
        for (j, raw) in scores.iter().enumerate() {
            let s = raw * q_inv;
            if s >= min_score {
                push_topk(
                    top,
                    Hit {
                        id: ids[i + j],
                        score: s,
                    },
                    k,
                );
            }
        }
    }
    for i in blocks * 4..n {
        let s = dot(query, &rows[i * dim..(i + 1) * dim]) * q_inv;
        if s >= min_score {
            push_topk(
                top,
                Hit {
                    id: ids[i],
                    score: s,
                },
                k,
            );
        }
    }
}

/// Row-by-row metric scan of contiguous row-major storage (the non-cosine
/// path; cosine callers use [`scan_cosine_rows`] over pre-normalized rows).
#[allow(clippy::too_many_arguments)]
pub(crate) fn scan_metric_rows(
    top: &mut Vec<Hit>,
    metric: Metric,
    query: &[f32],
    ids: &[u64],
    rows: &[f32],
    dim: usize,
    k: usize,
    min_score: f32,
) {
    for (i, &id) in ids.iter().enumerate() {
        let s = metric.score(query, &rows[i * dim..(i + 1) * dim]);
        if s >= min_score {
            push_topk(top, Hit { id, score: s }, k);
        }
    }
}

/// Maintain a bounded top-k set (small k: insertion into a sorted vec).
pub(crate) fn push_topk(heap: &mut Vec<Hit>, hit: Hit, k: usize) {
    if heap.len() < k {
        let pos = heap.partition_point(|h| h.score > hit.score);
        heap.insert(pos, hit);
    } else if let Some(last) = heap.last() {
        if hit.score > last.score {
            heap.pop();
            let pos = heap.partition_point(|h| h.score > hit.score);
            heap.insert(pos, hit);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_scores() {
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        let c = [2.0, 0.0];
        assert!((Metric::Cosine.score(&a, &c) - 1.0).abs() < 1e-6);
        assert!(Metric::Cosine.score(&a, &b).abs() < 1e-6);
        assert_eq!(Metric::Dot.score(&a, &c), 2.0);
        assert!((Metric::L2.score(&a, &c) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(5);
        for len in [0, 1, 7, 8, 9, 63, 64, 65] {
            let a: Vec<f32> = (0..len).map(|_| r.normal() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| r.normal() as f32).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "len={len}");
        }
    }

    #[test]
    fn dot4_matches_per_row_dot() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(9);
        for dim in [1, 7, 8, 16, 64] {
            let q: Vec<f32> = (0..dim).map(|_| r.normal() as f32).collect();
            let rows: Vec<f32> = (0..4 * dim).map(|_| r.normal() as f32).collect();
            let block = dot4(&q, &rows, dim);
            for j in 0..4 {
                let row = &rows[j * dim..(j + 1) * dim];
                assert!((block[j] - dot(&q, row)).abs() < 1e-3, "dim={dim} row={j}");
            }
        }
    }

    #[test]
    fn normalize_unit_norm_and_zero_safe() {
        let mut v = vec![3.0f32, 4.0];
        normalize_in_place(&mut v);
        assert!((dot(&v, &v).sqrt() - 1.0).abs() < 1e-6);
        let mut z = vec![0.0f32; 4];
        normalize_in_place(&mut z);
        assert!(z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn topk_maintains_order_and_bound() {
        let mut heap = Vec::new();
        for (i, s) in [0.1f32, 0.9, 0.5, 0.7, 0.3].iter().enumerate() {
            push_topk(&mut heap, Hit { id: i as u64, score: *s }, 3);
        }
        let scores: Vec<f32> = heap.iter().map(|h| h.score).collect();
        assert_eq!(scores, vec![0.9, 0.7, 0.5]);
    }
}
