//! Vector-database substrate — stand-in for the paper's RDS + vector-search
//! extension (§4). Stores fixed-dimension f32 vectors with u64 ids and
//! answers top-k similarity queries with an optional score threshold.
//!
//! Two index implementations behind [`VectorIndex`]:
//! * [`flat::FlatIndex`] — contiguous brute-force scan (exact).
//! * [`ivf::IvfIndex`] — inverted-file index (k-means coarse quantizer with
//!   `nprobe` cell search), for the perf pass and the ablation bench.

pub mod flat;
pub mod ivf;

use anyhow::Result;

/// Similarity metric. Scores are "higher is better" for all metrics
/// (L2 is negated distance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Cosine,
    Dot,
    L2,
}

impl Metric {
    #[inline]
    pub fn score(&self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            Metric::Dot => dot(a, b),
            Metric::Cosine => {
                let na = dot(a, a).sqrt();
                let nb = dot(b, b).sqrt();
                if na == 0.0 || nb == 0.0 {
                    0.0
                } else {
                    dot(a, b) / (na * nb)
                }
            }
            Metric::L2 => {
                let mut s = 0.0;
                for i in 0..a.len() {
                    let d = a[i] - b[i];
                    s += d * d;
                }
                -s.sqrt()
            }
        }
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // Unrolled by 8: the vecdb scan is an L3 hot path (see benches/hotpath).
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for c in 0..chunks {
        let i = c * 8;
        for j in 0..8 {
            acc[j] += a[i + j] * b[i + j];
        }
    }
    let mut s: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// A search hit: id + similarity score (higher is better).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub id: u64,
    pub score: f32,
}

pub trait VectorIndex: Send {
    fn dim(&self) -> usize;
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()>;
    fn remove(&mut self, id: u64) -> bool;
    /// Top-k by score, filtered to score >= min_score.
    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit>;
}

/// Maintain a bounded top-k set (small k: insertion into a sorted vec).
pub(crate) fn push_topk(heap: &mut Vec<Hit>, hit: Hit, k: usize) {
    if heap.len() < k {
        let pos = heap.partition_point(|h| h.score > hit.score);
        heap.insert(pos, hit);
    } else if let Some(last) = heap.last() {
        if hit.score > last.score {
            heap.pop();
            let pos = heap.partition_point(|h| h.score > hit.score);
            heap.insert(pos, hit);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_scores() {
        let a = [1.0, 0.0];
        let b = [0.0, 1.0];
        let c = [2.0, 0.0];
        assert!((Metric::Cosine.score(&a, &c) - 1.0).abs() < 1e-6);
        assert!(Metric::Cosine.score(&a, &b).abs() < 1e-6);
        assert_eq!(Metric::Dot.score(&a, &c), 2.0);
        assert!((Metric::L2.score(&a, &c) + 1.0).abs() < 1e-6);
    }

    #[test]
    fn dot_unrolled_matches_naive() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(5);
        for len in [0, 1, 7, 8, 9, 63, 64, 65] {
            let a: Vec<f32> = (0..len).map(|_| r.normal() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| r.normal() as f32).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "len={len}");
        }
    }

    #[test]
    fn topk_maintains_order_and_bound() {
        let mut heap = Vec::new();
        for (i, s) in [0.1f32, 0.9, 0.5, 0.7, 0.3].iter().enumerate() {
            push_topk(&mut heap, Hit { id: i as u64, score: *s }, 3);
        }
        let scores: Vec<f32> = heap.iter().map(|h| h.score).collect();
        assert_eq!(scores, vec![0.9, 0.7, 0.5]);
    }
}
