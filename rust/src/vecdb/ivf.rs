//! IVF (inverted-file) approximate index: k-means coarse quantizer, each
//! vector assigned to its nearest centroid's posting list; queries probe the
//! `nprobe` nearest cells. Trades a small recall loss for sub-linear scans —
//! the large-corpus tier of [`super::adaptive::AdaptiveIndex`].
//!
//! Storage layout (the IVF half of the cache hot path):
//! * Each posting list is **contiguous row-major storage** (`list_rows[c]`)
//!   with a parallel id vector, so a probed cell scans with the same
//!   blocked dot4 kernel as the flat index — not a pointer chase over
//!   per-vector heap allocations.
//! * An id → (cell, slot) map makes [`IvfIndex::remove`] O(1) (swap-remove
//!   within the cell, map fix-up for the displaced row) and
//!   [`IvfIndex::contains`] O(1) — the features the flat index already had,
//!   required once the semantic cache can sit on either tier.
//! * [`IvfIndex::from_trained_parts`] is the validated bulk-load path: a
//!   snapshot restores centroids + rows + assignments wholesale and never
//!   re-runs k-means.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::{dot, normalize_in_place, Hit, Metric, VectorIndex};
use crate::util::rng::Rng;

/// `locs` cell tag for vectors inserted before training.
const PENDING_CELL: u32 = u32::MAX;

#[derive(Debug)]
pub struct IvfIndex {
    dim: usize,
    metric: Metric,
    nlist: usize,
    pub nprobe: usize,
    /// nlist x dim, empty until trained.
    centroids: Vec<f32>,
    /// Per-cell ids, parallel to `list_rows`.
    list_ids: Vec<Vec<u64>>,
    /// Per-cell contiguous row-major vectors.
    list_rows: Vec<Vec<f32>>,
    /// Inserted before training (flat id/row arrays, scanned exactly).
    pending_ids: Vec<u64>,
    pending_rows: Vec<f32>,
    /// id → (cell, slot); cell == [`PENDING_CELL`] while untrained.
    locs: HashMap<u64, (u32, u32)>,
    trained: bool,
}

// ----------------------------------------------------------- k-means core

/// Index of the centroid with the best metric score for `v`.
pub(crate) fn nearest_centroid(metric: Metric, centroids: &[f32], dim: usize, v: &[f32]) -> usize {
    let k = centroids.len() / dim;
    debug_assert!(k > 0);
    let mut best = 0;
    let mut best_score = f32::MIN;
    for c in 0..k {
        let s = metric.score(v, &centroids[c * dim..(c + 1) * dim]);
        if s > best_score {
            best_score = s;
            best = c;
        }
    }
    best
}

/// The `n` cells with the best centroid score for `v`, best first — the
/// probe order both IVF tiers (f32 and quantized) share.
pub(crate) fn nearest_cells(
    metric: Metric,
    centroids: &[f32],
    dim: usize,
    v: &[f32],
    n: usize,
) -> Vec<usize> {
    let nlist = centroids.len() / dim;
    let mut scored: Vec<(usize, f32)> = (0..nlist)
        .map(|c| (c, metric.score(v, &centroids[c * dim..(c + 1) * dim])))
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    scored.truncate(n);
    scored.into_iter().map(|(c, _)| c).collect()
}

/// Lloyd's k-means over contiguous row-major `rows` (fixed iterations,
/// random distinct seeding). Returns `min(k, n) * dim` centroids. Shared by
/// [`IvfIndex::train`] and the adaptive tier's off-read-path retrain.
pub(crate) fn kmeans_centroids(
    rng: &mut Rng,
    metric: Metric,
    rows: &[f32],
    dim: usize,
    k: usize,
    iters: usize,
) -> Vec<f32> {
    let n = rows.len() / dim;
    debug_assert!(n > 0);
    let k = k.max(1).min(n);
    let picks = rng.sample_indices(n, k);
    let mut centroids: Vec<f32> = picks
        .iter()
        .flat_map(|&i| rows[i * dim..(i + 1) * dim].iter().copied())
        .collect();
    let mut assign = vec![0usize; n];
    for _ in 0..iters {
        for (i, a) in assign.iter_mut().enumerate() {
            *a = nearest_centroid(metric, &centroids, dim, &rows[i * dim..(i + 1) * dim]);
        }
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0usize; k];
        for (i, &c) in assign.iter().enumerate() {
            counts[c] += 1;
            for (j, x) in rows[i * dim..(i + 1) * dim].iter().enumerate() {
                sums[c * dim + j] += *x as f64;
            }
        }
        for c in 0..k {
            // An empty cell keeps its previous centroid.
            if counts[c] > 0 {
                for j in 0..dim {
                    centroids[c * dim + j] = (sums[c * dim + j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    centroids
}

/// Remove row `slot` from an (ids, row-major rows) pair by swap-remove.
/// Returns the id that moved into `slot`, if any.
fn swap_remove_row(
    ids: &mut Vec<u64>,
    rows: &mut Vec<f32>,
    dim: usize,
    slot: usize,
) -> Option<u64> {
    let last = ids.len() - 1;
    ids.swap(slot, last);
    ids.pop();
    if slot != last {
        let (head, tail) = rows.split_at_mut(last * dim);
        head[slot * dim..(slot + 1) * dim].copy_from_slice(&tail[..dim]);
    }
    rows.truncate(last * dim);
    if slot != last {
        Some(ids[slot])
    } else {
        None
    }
}

impl IvfIndex {
    pub fn new(dim: usize, metric: Metric, nlist: usize, nprobe: usize) -> IvfIndex {
        IvfIndex {
            dim,
            metric,
            nlist: nlist.max(1),
            nprobe: nprobe.max(1),
            centroids: Vec::new(),
            list_ids: Vec::new(),
            list_rows: Vec::new(),
            pending_ids: Vec::new(),
            pending_rows: Vec::new(),
            locs: HashMap::new(),
            trained: false,
        }
    }

    pub fn is_trained(&self) -> bool {
        self.trained
    }

    pub fn metric(&self) -> Metric {
        self.metric
    }

    /// Number of coarse cells (after training, `min(nlist, n)` at train
    /// time).
    pub fn nlist(&self) -> usize {
        self.nlist
    }

    /// Trained centroids, row-major `nlist x dim` (empty until trained).
    pub fn centroids(&self) -> &[f32] {
        &self.centroids
    }

    /// Whether `id` has a row (O(1) via the id→(cell, slot) map).
    pub fn contains(&self, id: u64) -> bool {
        self.locs.contains_key(&id)
    }

    /// The `n` cells with the best centroid score for `v`, best first.
    fn nearest_cells(&self, v: &[f32], n: usize) -> Vec<usize> {
        nearest_cells(self.metric, &self.centroids, self.dim, v, n)
    }

    /// Insert a vector that is already in stored form (cosine rows
    /// pre-normalized) — the migration/reconcile path, which must not
    /// re-normalize rows the flat tier already normalized.
    pub(crate) fn insert_stored(&mut self, id: u64, v: &[f32]) -> Result<()> {
        if v.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", v.len(), self.dim);
        }
        if self.trained {
            let c = nearest_centroid(self.metric, &self.centroids, self.dim, v);
            let slot = self.list_ids[c].len() as u32;
            self.list_ids[c].push(id);
            self.list_rows[c].extend_from_slice(v);
            self.locs.insert(id, (c as u32, slot));
        } else {
            let slot = self.pending_ids.len() as u32;
            self.pending_ids.push(id);
            self.pending_rows.extend_from_slice(v);
            self.locs.insert(id, (PENDING_CELL, slot));
        }
        Ok(())
    }

    /// Train the coarse quantizer with Lloyd's k-means (fixed iterations)
    /// over all pending vectors, then assign them to cells.
    pub fn train(&mut self, seed: u64, iters: usize) -> Result<()> {
        if self.trained {
            bail!("index is already trained");
        }
        if self.pending_ids.is_empty() {
            bail!("no vectors to train on");
        }
        let n = self.pending_ids.len();
        let k = self.nlist.min(n);
        self.nlist = k;
        let mut rng = Rng::new(seed);
        self.centroids =
            kmeans_centroids(&mut rng, self.metric, &self.pending_rows, self.dim, k, iters);
        self.list_ids = vec![Vec::new(); k];
        self.list_rows = vec![Vec::new(); k];
        self.locs.clear();
        self.trained = true;
        let ids = std::mem::take(&mut self.pending_ids);
        let rows = std::mem::take(&mut self.pending_rows);
        for (i, id) in ids.into_iter().enumerate() {
            let row = &rows[i * self.dim..(i + 1) * self.dim];
            let c = nearest_centroid(self.metric, &self.centroids, self.dim, row);
            let slot = self.list_ids[c].len() as u32;
            self.list_ids[c].push(id);
            self.list_rows[c].extend_from_slice(row);
            self.locs.insert(id, (c as u32, slot));
        }
        Ok(())
    }

    /// Validated bulk load of a **trained** index: centroids + slot-ordered
    /// ids/rows + per-row cell assignments, exactly as
    /// [`IvfIndex::export_parts`] produced them. Rows are adopted verbatim
    /// (cosine rows were stored pre-normalized), so a restore never
    /// re-trains and scores stay bit-identical. Rejects geometry mismatches,
    /// out-of-range assignments, and duplicate ids.
    #[allow(clippy::too_many_arguments)]
    pub fn from_trained_parts(
        dim: usize,
        metric: Metric,
        nprobe: usize,
        centroids: Vec<f32>,
        ids: Vec<u64>,
        rows: Vec<f32>,
        assignments: &[u32],
    ) -> Result<IvfIndex> {
        if dim == 0 {
            bail!("ivf snapshot: dim must be positive");
        }
        if centroids.is_empty() || centroids.len() % dim != 0 {
            bail!(
                "ivf snapshot: {} centroid floats is not a positive multiple of dim {dim}",
                centroids.len()
            );
        }
        let nlist = centroids.len() / dim;
        if rows.len() != ids.len() * dim {
            bail!(
                "ivf snapshot: {} row floats for {} ids at dim {dim}",
                rows.len(),
                ids.len()
            );
        }
        if assignments.len() != ids.len() {
            bail!(
                "ivf snapshot: {} assignments for {} ids",
                assignments.len(),
                ids.len()
            );
        }
        let mut idx = IvfIndex {
            dim,
            metric,
            nlist,
            nprobe: nprobe.max(1),
            centroids,
            list_ids: vec![Vec::new(); nlist],
            list_rows: vec![Vec::new(); nlist],
            pending_ids: Vec::new(),
            pending_rows: Vec::new(),
            locs: HashMap::with_capacity(ids.len()),
            trained: true,
        };
        for (i, (&id, &cell)) in ids.iter().zip(assignments).enumerate() {
            let c = cell as usize;
            if c >= nlist {
                bail!("ivf snapshot: row {i} assigned to cell {c} of {nlist}");
            }
            let slot = idx.list_ids[c].len() as u32;
            idx.list_ids[c].push(id);
            idx.list_rows[c].extend_from_slice(&rows[i * dim..(i + 1) * dim]);
            if idx.locs.insert(id, (cell, slot)).is_some() {
                bail!("ivf snapshot: duplicate id {id}");
            }
        }
        Ok(idx)
    }

    /// Flatten a trained index for snapshotting: slot-ordered `(ids, rows,
    /// assignments)` that [`IvfIndex::from_trained_parts`] round-trips.
    pub fn export_parts(&self) -> (Vec<u64>, Vec<f32>, Vec<u32>) {
        let n = self.locs.len();
        let mut ids = Vec::with_capacity(n);
        let mut rows = Vec::with_capacity(n * self.dim);
        let mut assignments = Vec::with_capacity(n);
        if self.trained {
            for c in 0..self.nlist {
                ids.extend_from_slice(&self.list_ids[c]);
                rows.extend_from_slice(&self.list_rows[c]);
                assignments.extend(std::iter::repeat(c as u32).take(self.list_ids[c].len()));
            }
        } else {
            ids.extend_from_slice(&self.pending_ids);
            rows.extend_from_slice(&self.pending_rows);
        }
        (ids, rows, assignments)
    }

    /// Visit every `(id, row)` pair (arbitrary but stable order).
    pub(crate) fn for_each_row(&self, mut f: impl FnMut(u64, &[f32])) {
        if self.trained {
            for c in 0..self.nlist {
                for (i, &id) in self.list_ids[c].iter().enumerate() {
                    f(id, &self.list_rows[c][i * self.dim..(i + 1) * self.dim]);
                }
            }
        } else {
            for (i, &id) in self.pending_ids.iter().enumerate() {
                f(id, &self.pending_rows[i * self.dim..(i + 1) * self.dim]);
            }
        }
    }

    /// Top-k over the `probes` nearest cells (the widening knob the cache's
    /// over-fetch GET escalates; plain [`VectorIndex::search`] uses
    /// `self.nprobe`). Untrained indexes scan pending exactly.
    pub fn search_probes(
        &self,
        query: &[f32],
        k: usize,
        min_score: f32,
        probes: usize,
    ) -> Vec<Hit> {
        let mut top: Vec<Hit> = Vec::with_capacity(k + 1);
        if k == 0 {
            return top;
        }
        // Stored cosine rows are unit-normalized: score = dot / |q|.
        let q_inv = if self.metric == Metric::Cosine {
            let n = dot(query, query).sqrt();
            if n == 0.0 {
                0.0
            } else {
                1.0 / n
            }
        } else {
            0.0
        };
        let mut scan = |ids: &[u64], rows: &[f32]| {
            if self.metric == Metric::Cosine {
                super::scan_cosine_rows(&mut top, query, q_inv, ids, rows, self.dim, k, min_score);
            } else {
                super::scan_metric_rows(
                    &mut top,
                    self.metric,
                    query,
                    ids,
                    rows,
                    self.dim,
                    k,
                    min_score,
                );
            }
        };
        if !self.trained {
            scan(&self.pending_ids, &self.pending_rows);
            return top;
        }
        for c in self.nearest_cells(query, probes.max(1)) {
            scan(&self.list_ids[c], &self.list_rows[c]);
        }
        top
    }
}

impl VectorIndex for IvfIndex {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.locs.len()
    }

    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()> {
        if vector.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", vector.len(), self.dim);
        }
        let mut v = vector.to_vec();
        if self.metric == Metric::Cosine {
            // Stored pre-normalized (same as FlatIndex) so the posting-list
            // scan is a pure dot; cosine is normalization-invariant, so
            // cell assignment and scores are unchanged.
            normalize_in_place(&mut v);
        }
        self.insert_stored(id, &v)
    }

    fn remove(&mut self, id: u64) -> bool {
        let Some((cell, slot)) = self.locs.remove(&id) else {
            return false;
        };
        let moved = if cell == PENDING_CELL {
            swap_remove_row(
                &mut self.pending_ids,
                &mut self.pending_rows,
                self.dim,
                slot as usize,
            )
        } else {
            let c = cell as usize;
            swap_remove_row(
                &mut self.list_ids[c],
                &mut self.list_rows[c],
                self.dim,
                slot as usize,
            )
        };
        if let Some(moved_id) = moved {
            self.locs.insert(moved_id, (cell, slot));
        }
        true
    }

    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit> {
        self.search_probes(query, k, min_score, self.nprobe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdb::flat::FlatIndex;

    fn clustered_data(seed: u64, n: usize, dim: usize) -> Vec<(u64, Vec<f32>)> {
        // Points around 8 well-separated centers.
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..dim).map(|_| rng.normal() as f32 * 10.0).collect())
            .collect();
        (0..n)
            .map(|i| {
                let c = rng.choice(&centers).clone();
                let v = c
                    .iter()
                    .map(|x| x + rng.normal() as f32 * 0.5)
                    .collect();
                (i as u64, v)
            })
            .collect()
    }

    #[test]
    fn untrained_falls_back_to_exact() {
        let mut ivf = IvfIndex::new(4, Metric::Cosine, 4, 1);
        ivf.insert(1, &[1.0, 0.0, 0.0, 0.0]).unwrap();
        ivf.insert(2, &[0.0, 1.0, 0.0, 0.0]).unwrap();
        let hits = ivf.search(&[1.0, 0.0, 0.0, 0.0], 1, 0.0);
        assert_eq!(hits[0].id, 1);
    }

    #[test]
    fn trained_recall_vs_flat() {
        let data = clustered_data(3, 400, 16);
        let mut ivf = IvfIndex::new(16, Metric::L2, 8, 3);
        let mut flat = FlatIndex::new(16, Metric::L2);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
            flat.insert(*id, v).unwrap();
        }
        ivf.train(7, 5).unwrap();
        assert!(ivf.is_trained());
        assert_eq!(ivf.len(), 400);
        // Recall@5 over 20 queries should be high on clustered data.
        let mut rng = Rng::new(11);
        let mut hits_found = 0;
        let mut total = 0;
        for _ in 0..20 {
            let (_, q) = rng.choice(&data).clone();
            let truth: Vec<u64> =
                flat.search(&q, 5, f32::MIN).iter().map(|h| h.id).collect();
            let got: Vec<u64> =
                ivf.search(&q, 5, f32::MIN).iter().map(|h| h.id).collect();
            total += truth.len();
            hits_found += truth.iter().filter(|t| got.contains(t)).count();
        }
        let recall = hits_found as f64 / total as f64;
        assert!(recall > 0.8, "recall={recall}");
    }

    #[test]
    fn insert_after_training_lands_in_cell() {
        let data = clustered_data(5, 100, 8);
        let mut ivf = IvfIndex::new(8, Metric::L2, 4, 4);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
        }
        ivf.train(1, 4).unwrap();
        ivf.insert(9999, &data[0].1.clone()).unwrap();
        assert!(ivf.contains(9999));
        let hits = ivf.search(&data[0].1, 2, f32::MIN);
        assert!(hits.iter().any(|h| h.id == 9999));
    }

    #[test]
    fn remove_works_pre_and_post_training() {
        let data = clustered_data(9, 50, 8);
        let mut ivf = IvfIndex::new(8, Metric::L2, 4, 4);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
        }
        assert!(ivf.contains(10));
        assert!(ivf.remove(10));
        assert!(!ivf.contains(10));
        ivf.train(1, 3).unwrap();
        assert!(ivf.remove(20));
        assert!(!ivf.remove(20));
        assert_eq!(ivf.len(), 48);
        // Every surviving id is still findable after the swap-removes.
        for (id, _) in &data {
            if *id != 10 && *id != 20 {
                assert!(ivf.contains(*id), "id {id} lost by remove fix-up");
            }
        }
    }

    /// Randomized remove/re-insert churn: the id→(cell, slot) map must stay
    /// consistent with the posting lists (the flat index's equivalent
    /// property, now required of the IVF tier).
    #[test]
    fn churn_keeps_locs_consistent() {
        let data = clustered_data(13, 300, 8);
        let mut ivf = IvfIndex::new(8, Metric::L2, 8, 8);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
        }
        ivf.train(5, 4).unwrap();
        let mut rng = Rng::new(31);
        let mut live: Vec<u64> = data.iter().map(|(id, _)| *id).collect();
        for round in 0..600 {
            if !live.is_empty() && rng.chance(0.5) {
                let pick = rng.below(live.len());
                let id = live.swap_remove(pick);
                assert!(ivf.remove(id), "round {round}: remove({id})");
                assert!(!ivf.contains(id));
            } else {
                let id = 10_000 + round as u64;
                let (_, v) = rng.choice(&data);
                ivf.insert(id, &v.clone()).unwrap();
                live.push(id);
            }
            assert_eq!(ivf.len(), live.len());
        }
        // Exhaustive probe finds exactly the live set.
        let got: std::collections::HashSet<u64> = ivf
            .search_probes(&data[0].1, live.len(), f32::MIN, ivf.nlist())
            .iter()
            .map(|h| h.id)
            .collect();
        assert_eq!(got.len(), live.len());
        for id in &live {
            assert!(ivf.contains(*id));
        }
    }

    /// export_parts → from_trained_parts is lossless: identical hits and
    /// bit-identical scores, with no retraining.
    #[test]
    fn trained_parts_roundtrip_bit_exact() {
        let data = clustered_data(17, 500, 16);
        let mut ivf = IvfIndex::new(16, Metric::Cosine, 12, 4);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
        }
        ivf.train(3, 4).unwrap();
        let (ids, rows, assignments) = ivf.export_parts();
        let back = IvfIndex::from_trained_parts(
            16,
            Metric::Cosine,
            ivf.nprobe,
            ivf.centroids().to_vec(),
            ids,
            rows,
            &assignments,
        )
        .unwrap();
        assert!(back.is_trained());
        assert_eq!(back.len(), ivf.len());
        assert_eq!(back.nlist(), ivf.nlist());
        let mut rng = Rng::new(23);
        for _ in 0..20 {
            let (_, q) = rng.choice(&data).clone();
            let a = ivf.search(&q, 6, f32::MIN);
            let b = back.search(&q, 6, f32::MIN);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits(), "score drifted");
            }
        }
    }

    #[test]
    fn from_trained_parts_rejects_bad_geometry() {
        let centroids = vec![0.0f32; 8]; // 2 cells x dim 4
        let ids = vec![1u64, 2];
        let rows = vec![0.5f32; 8];
        // Valid baseline.
        assert!(IvfIndex::from_trained_parts(
            4, Metric::Cosine, 2, centroids.clone(), ids.clone(), rows.clone(), &[0, 1],
        )
        .is_ok());
        // Assignment out of range.
        assert!(IvfIndex::from_trained_parts(
            4, Metric::Cosine, 2, centroids.clone(), ids.clone(), rows.clone(), &[0, 2],
        )
        .is_err());
        // Assignment count mismatch.
        assert!(IvfIndex::from_trained_parts(
            4, Metric::Cosine, 2, centroids.clone(), ids.clone(), rows.clone(), &[0],
        )
        .is_err());
        // Row floats don't match id count.
        assert!(IvfIndex::from_trained_parts(
            4, Metric::Cosine, 2, centroids.clone(), ids.clone(), vec![0.5f32; 7], &[0, 1],
        )
        .is_err());
        // Duplicate id.
        assert!(IvfIndex::from_trained_parts(
            4, Metric::Cosine, 2, centroids.clone(), vec![1, 1], rows, &[0, 1],
        )
        .is_err());
        // Centroids not a multiple of dim.
        assert!(IvfIndex::from_trained_parts(
            4, Metric::Cosine, 2, vec![0.0f32; 7], ids, vec![0.5f32; 8], &[0, 1],
        )
        .is_err());
    }
}
