//! IVF (inverted-file) approximate index: k-means coarse quantizer, each
//! vector assigned to its nearest centroid's posting list; queries probe the
//! `nprobe` nearest cells. Trades a small recall loss for sub-linear scans —
//! used in the perf pass when the cache corpus grows large.

use anyhow::{bail, Result};

use super::{dot, normalize_in_place, push_topk, Hit, Metric, VectorIndex};
use crate::util::rng::Rng;

pub struct IvfIndex {
    dim: usize,
    metric: Metric,
    nlist: usize,
    pub nprobe: usize,
    centroids: Vec<f32>,          // nlist x dim, empty until trained
    lists: Vec<Vec<(u64, Vec<f32>)>>,
    pending: Vec<(u64, Vec<f32>)>, // inserted before training
    trained: bool,
}

impl IvfIndex {
    pub fn new(dim: usize, metric: Metric, nlist: usize, nprobe: usize) -> IvfIndex {
        IvfIndex {
            dim,
            metric,
            nlist: nlist.max(1),
            nprobe: nprobe.max(1),
            centroids: Vec::new(),
            lists: Vec::new(),
            pending: Vec::new(),
            trained: false,
        }
    }

    pub fn is_trained(&self) -> bool {
        self.trained
    }

    fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }

    fn nearest_cells(&self, v: &[f32], n: usize) -> Vec<usize> {
        let mut scored: Vec<(usize, f32)> = (0..self.nlist)
            .map(|c| (c, self.metric.score(v, self.centroid(c))))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        scored.truncate(n);
        scored.into_iter().map(|(c, _)| c).collect()
    }

    /// Train the coarse quantizer with Lloyd's k-means (fixed iterations)
    /// over all pending vectors, then assign them to cells.
    pub fn train(&mut self, seed: u64, iters: usize) -> Result<()> {
        if self.pending.is_empty() {
            bail!("no vectors to train on");
        }
        let n = self.pending.len();
        let k = self.nlist.min(n);
        self.nlist = k;
        let mut rng = Rng::new(seed);
        // k-means++ style seeding: random distinct picks.
        let picks = rng.sample_indices(n, k);
        self.centroids = picks
            .iter()
            .flat_map(|&i| self.pending[i].1.iter().copied())
            .collect();
        let mut assign = vec![0usize; n];
        for _ in 0..iters {
            for (i, (_, v)) in self.pending.iter().enumerate() {
                assign[i] = self.nearest_cells(v, 1)[0];
            }
            let mut sums = vec![0.0f64; k * self.dim];
            let mut counts = vec![0usize; k];
            for (i, (_, v)) in self.pending.iter().enumerate() {
                let c = assign[i];
                counts[c] += 1;
                for (j, x) in v.iter().enumerate() {
                    sums[c * self.dim + j] += *x as f64;
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for j in 0..self.dim {
                        self.centroids[c * self.dim + j] =
                            (sums[c * self.dim + j] / counts[c] as f64) as f32;
                    }
                }
            }
        }
        self.lists = vec![Vec::new(); k];
        let pending = std::mem::take(&mut self.pending);
        self.trained = true;
        for (id, v) in pending {
            let c = self.nearest_cells(&v, 1)[0];
            self.lists[c].push((id, v));
        }
        Ok(())
    }
}

impl VectorIndex for IvfIndex {
    fn dim(&self) -> usize {
        self.dim
    }

    fn len(&self) -> usize {
        self.pending.len() + self.lists.iter().map(|l| l.len()).sum::<usize>()
    }

    fn insert(&mut self, id: u64, vector: &[f32]) -> Result<()> {
        if vector.len() != self.dim {
            bail!("dim mismatch: got {}, want {}", vector.len(), self.dim);
        }
        let mut v = vector.to_vec();
        if self.metric == Metric::Cosine {
            // Stored pre-normalized (same as FlatIndex) so the posting-list
            // scan is a pure dot; cosine is normalization-invariant, so
            // cell assignment and scores are unchanged.
            normalize_in_place(&mut v);
        }
        if self.trained {
            let c = self.nearest_cells(&v, 1)[0];
            self.lists[c].push((id, v));
        } else {
            self.pending.push((id, v));
        }
        Ok(())
    }

    fn remove(&mut self, id: u64) -> bool {
        if let Some(i) = self.pending.iter().position(|(x, _)| *x == id) {
            self.pending.swap_remove(i);
            return true;
        }
        for list in &mut self.lists {
            if let Some(i) = list.iter().position(|(x, _)| *x == id) {
                list.swap_remove(i);
                return true;
            }
        }
        false
    }

    fn search(&self, query: &[f32], k: usize, min_score: f32) -> Vec<Hit> {
        let mut top: Vec<Hit> = Vec::with_capacity(k + 1);
        // Stored cosine vectors are unit-normalized: score = dot / |q|,
        // computed without re-deriving the row norm per query.
        let q_inv = if self.metric == Metric::Cosine {
            let n = dot(query, query).sqrt();
            if n == 0.0 {
                0.0
            } else {
                1.0 / n
            }
        } else {
            0.0
        };
        let score_of = |v: &[f32]| -> f32 {
            if self.metric == Metric::Cosine {
                dot(query, v) * q_inv
            } else {
                self.metric.score(query, v)
            }
        };
        if !self.trained {
            // Fallback: exact scan over pending.
            for (id, v) in &self.pending {
                let s = score_of(v);
                if s >= min_score {
                    push_topk(&mut top, Hit { id: *id, score: s }, k);
                }
            }
            return top;
        }
        for c in self.nearest_cells(query, self.nprobe) {
            for (id, v) in &self.lists[c] {
                let s = score_of(v);
                if s >= min_score {
                    push_topk(&mut top, Hit { id: *id, score: s }, k);
                }
            }
        }
        top
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vecdb::flat::FlatIndex;

    fn clustered_data(seed: u64, n: usize, dim: usize) -> Vec<(u64, Vec<f32>)> {
        // Points around 8 well-separated centers.
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..dim).map(|_| rng.normal() as f32 * 10.0).collect())
            .collect();
        (0..n)
            .map(|i| {
                let c = rng.choice(&centers).clone();
                let v = c
                    .iter()
                    .map(|x| x + rng.normal() as f32 * 0.5)
                    .collect();
                (i as u64, v)
            })
            .collect()
    }

    #[test]
    fn untrained_falls_back_to_exact() {
        let mut ivf = IvfIndex::new(4, Metric::Cosine, 4, 1);
        ivf.insert(1, &[1.0, 0.0, 0.0, 0.0]).unwrap();
        ivf.insert(2, &[0.0, 1.0, 0.0, 0.0]).unwrap();
        let hits = ivf.search(&[1.0, 0.0, 0.0, 0.0], 1, 0.0);
        assert_eq!(hits[0].id, 1);
    }

    #[test]
    fn trained_recall_vs_flat() {
        let data = clustered_data(3, 400, 16);
        let mut ivf = IvfIndex::new(16, Metric::L2, 8, 3);
        let mut flat = FlatIndex::new(16, Metric::L2);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
            flat.insert(*id, v).unwrap();
        }
        ivf.train(7, 5).unwrap();
        assert!(ivf.is_trained());
        assert_eq!(ivf.len(), 400);
        // Recall@5 over 20 queries should be high on clustered data.
        let mut rng = Rng::new(11);
        let mut hits_found = 0;
        let mut total = 0;
        for _ in 0..20 {
            let (_, q) = rng.choice(&data).clone();
            let truth: Vec<u64> =
                flat.search(&q, 5, f32::MIN).iter().map(|h| h.id).collect();
            let got: Vec<u64> =
                ivf.search(&q, 5, f32::MIN).iter().map(|h| h.id).collect();
            total += truth.len();
            hits_found += truth.iter().filter(|t| got.contains(t)).count();
        }
        let recall = hits_found as f64 / total as f64;
        assert!(recall > 0.8, "recall={recall}");
    }

    #[test]
    fn insert_after_training_lands_in_cell() {
        let data = clustered_data(5, 100, 8);
        let mut ivf = IvfIndex::new(8, Metric::L2, 4, 4);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
        }
        ivf.train(1, 4).unwrap();
        ivf.insert(9999, &data[0].1.clone()).unwrap();
        let hits = ivf.search(&data[0].1, 2, f32::MIN);
        assert!(hits.iter().any(|h| h.id == 9999));
    }

    #[test]
    fn remove_works_pre_and_post_training() {
        let data = clustered_data(9, 50, 8);
        let mut ivf = IvfIndex::new(8, Metric::L2, 4, 4);
        for (id, v) in &data {
            ivf.insert(*id, v).unwrap();
        }
        assert!(ivf.remove(10));
        ivf.train(1, 3).unwrap();
        assert!(ivf.remove(20));
        assert!(!ivf.remove(20));
        assert_eq!(ivf.len(), 48);
    }
}
