//! Telemetry substrate: counters, latency histograms (p50/p99/p99.9), and a
//! per-model cost ledger. Everything is lock-light so the request hot path
//! never blocks on metrics: histograms are pure atomics, counters are
//! atomics behind a read-mostly `RwLock` name map (the write lock is taken
//! only the first time a counter name appears), and the cost ledger keeps a
//! short mutex (multi-field updates).

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use crate::util::json::Json;

/// Log-bucketed latency histogram: 1us .. ~137s in 5% geometric steps.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

const HIST_BUCKETS: usize = 384;
const HIST_BASE_US: f64 = 1.0;
const HIST_GROWTH: f64 = 1.05;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: f64) -> usize {
        if us <= HIST_BASE_US {
            return 0;
        }
        let b = (us / HIST_BASE_US).ln() / HIST_GROWTH.ln();
        (b as usize).min(HIST_BUCKETS - 1)
    }

    fn bucket_upper_us(idx: usize) -> f64 {
        HIST_BASE_US * HIST_GROWTH.powi(idx as i32 + 1)
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.buckets[Self::bucket_of(us as f64)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / n)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    /// Quantile in [0,1]; returns the upper edge of the containing bucket.
    pub fn quantile(&self, q: f64) -> Duration {
        let n = self.count();
        if n == 0 {
            return Duration::ZERO;
        }
        let target = ((n as f64) * q).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_micros(Self::bucket_upper_us(i) as u64);
            }
        }
        self.max()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("mean_us", Json::num(self.mean().as_micros() as f64)),
            ("p50_us", Json::num(self.quantile(0.50).as_micros() as f64)),
            ("p99_us", Json::num(self.quantile(0.99).as_micros() as f64)),
            ("p999_us", Json::num(self.quantile(0.999).as_micros() as f64)),
            ("max_us", Json::num(self.max().as_micros() as f64)),
        ])
    }
}

/// Named monotonically-increasing counters. Increments on an existing
/// counter are a shared read lock + one atomic add, so concurrent requests
/// bumping the same hot counter (`requests`, `cache_exact_hits`, …) never
/// serialize; the write lock is only taken to register a new name.
#[derive(Default)]
pub struct Counters {
    inner: RwLock<HashMap<String, Arc<AtomicU64>>>,
}

impl Counters {
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    pub fn add(&self, name: &str, by: u64) {
        if let Some(c) = self.inner.read().unwrap().get(name) {
            c.fetch_add(by, Ordering::Relaxed);
            return;
        }
        self.inner
            .write()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(AtomicU64::new(0)))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner
            .read()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    pub fn to_json(&self) -> Json {
        let m = self.inner.read().unwrap();
        // BTreeMap intermediate keeps the output deterministically sorted.
        let sorted: BTreeMap<String, u64> = m
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        Json::Obj(
            sorted
                .into_iter()
                .map(|(k, v)| (k, Json::num(v as f64)))
                .collect(),
        )
    }
}

/// Cost ledger: micro-dollars per model, split input/output tokens.
#[derive(Default)]
pub struct CostLedger {
    inner: Mutex<BTreeMap<String, ModelCost>>,
}

#[derive(Default, Clone, Debug)]
pub struct ModelCost {
    pub calls: u64,
    pub input_tokens: u64,
    pub output_tokens: u64,
    pub cost_usd: f64,
}

impl CostLedger {
    pub fn record(&self, model: &str, input_tokens: u64, output_tokens: u64, cost_usd: f64) {
        let mut m = self.inner.lock().unwrap();
        let e = m.entry(model.to_string()).or_default();
        e.calls += 1;
        e.input_tokens += input_tokens;
        e.output_tokens += output_tokens;
        e.cost_usd += cost_usd;
    }

    pub fn total_usd(&self) -> f64 {
        self.inner.lock().unwrap().values().map(|e| e.cost_usd).sum()
    }

    pub fn total_tokens(&self) -> (u64, u64) {
        let m = self.inner.lock().unwrap();
        (
            m.values().map(|e| e.input_tokens).sum(),
            m.values().map(|e| e.output_tokens).sum(),
        )
    }

    pub fn per_model(&self) -> BTreeMap<String, ModelCost> {
        self.inner.lock().unwrap().clone()
    }

    pub fn to_json(&self) -> Json {
        let m = self.inner.lock().unwrap();
        Json::Obj(
            m.iter()
                .map(|(k, v)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("calls", Json::num(v.calls as f64)),
                            ("input_tokens", Json::num(v.input_tokens as f64)),
                            ("output_tokens", Json::num(v.output_tokens as f64)),
                            ("cost_usd", Json::Num(v.cost_usd)),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

/// Everything the proxy records, shared via Arc.
#[derive(Default)]
pub struct Telemetry {
    pub counters: Counters,
    pub request_latency: Histogram,
    pub llm_latency_small: Histogram,
    pub llm_latency_large: Histogram,
    pub costs: CostLedger,
}

impl Telemetry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("counters", self.counters.to_json()),
            ("request_latency", self.request_latency.to_json()),
            ("llm_latency_small", self.llm_latency_small.to_json()),
            ("llm_latency_large", self.llm_latency_large.to_json()),
            ("costs", self.costs.to_json()),
            ("total_cost_usd", Json::Num(self.costs.total_usd())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 10));
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        let p999 = h.quantile(0.999);
        assert!(p50 <= p99 && p99 <= p999);
        // p50 of 10..10000us uniform should be near 5000us (log buckets: ±5%).
        let p50us = p50.as_micros() as f64;
        assert!((4500.0..5800.0).contains(&p50us), "p50={p50us}");
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn cost_ledger_accumulates() {
        let c = CostLedger::default();
        c.record("gpt-4", 1000, 100, 0.036);
        c.record("gpt-4", 500, 50, 0.018);
        c.record("gpt-3.5-turbo", 1000, 100, 0.00065);
        let per = c.per_model();
        assert_eq!(per["gpt-4"].calls, 2);
        assert_eq!(per["gpt-4"].input_tokens, 1500);
        assert!((c.total_usd() - 0.05465).abs() < 1e-9);
    }

    #[test]
    fn counters() {
        let c = Counters::default();
        c.incr("cache_hit");
        c.add("cache_hit", 2);
        assert_eq!(c.get("cache_hit"), 3);
        assert_eq!(c.get("missing"), 0);
    }

    #[test]
    fn counters_concurrent_increments_are_lossless() {
        let c = Counters::default();
        let threads = 8;
        let per_thread = 1000u64;
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    for i in 0..per_thread {
                        c.incr("requests");
                        if i % 4 == 0 {
                            c.add("cache_exact_hits", 1);
                        }
                    }
                });
            }
        });
        assert_eq!(c.get("requests"), threads * per_thread);
        assert_eq!(c.get("cache_exact_hits"), threads * per_thread / 4);
    }
}
