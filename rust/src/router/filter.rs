//! Attribute-based selection over the model pool (§3.3's "filter based
//! interface") and the cascade-role resolver — the raw scoring primitives
//! the routing policies are built from.

use anyhow::{bail, Result};

use crate::models::pricing::{Generation, LatencyClass, ModelId, ModelSpec, POOL};

/// Attribute filter over the model pool.
#[derive(Clone, Debug, Default)]
pub struct PoolFilter {
    pub family: Option<&'static str>,
    pub generation: Option<Generation>,
    pub max_usd_per_mtok_in: Option<f64>,
    pub min_capability: Option<f64>,
    pub min_context_window: Option<u64>,
    pub latency_class: Option<LatencyClass>,
    pub allowed: Option<Vec<ModelId>>,
}

impl PoolFilter {
    pub fn matches(&self, spec: &ModelSpec) -> bool {
        if let Some(f) = self.family {
            if spec.family != f {
                return false;
            }
        }
        if let Some(g) = self.generation {
            if spec.generation != g {
                return false;
            }
        }
        if let Some(p) = self.max_usd_per_mtok_in {
            if spec.usd_per_mtok_in > p {
                return false;
            }
        }
        if let Some(c) = self.min_capability {
            if spec.capability < c {
                return false;
            }
        }
        if let Some(w) = self.min_context_window {
            if spec.context_window < w {
                return false;
            }
        }
        if let Some(l) = self.latency_class {
            if spec.latency_class != l {
                return false;
            }
        }
        if let Some(allowed) = &self.allowed {
            if !allowed.contains(&spec.id) {
                return false;
            }
        }
        true
    }

    pub fn select(&self) -> Vec<&'static ModelSpec> {
        POOL.iter().filter(|m| self.matches(m)).collect()
    }

    /// Cheapest (by input price) matching model.
    pub fn cheapest(&self) -> Result<ModelId> {
        crate::models::pricing::min_price_of(self.select())
            .ok_or_else(|| anyhow::anyhow!("no model matches filter"))
    }

    /// Highest-capability matching model.
    pub fn best(&self) -> Result<ModelId> {
        self.select()
            .into_iter()
            .max_by(|a, b| a.capability.partial_cmp(&b.capability).unwrap())
            .map(|m| m.id)
            .ok_or_else(|| anyhow::anyhow!("no model matches filter"))
    }
}

/// Pick (m1, m2, verifier) for the cascade under the §3.3 heuristic:
/// `cost(verifier) <= cost(m1) <= cost(m2)` by per-token price — unless
/// the application pinned specific models.
pub fn cascade_models(
    generation: Generation,
    m1: Option<ModelId>,
    m2: Option<ModelId>,
    verifier: Option<ModelId>,
) -> Result<(ModelId, ModelId, ModelId)> {
    let gen_filter = PoolFilter {
        generation: Some(generation),
        ..Default::default()
    };
    let candidates = gen_filter.select();
    if candidates.is_empty() {
        bail!("empty pool for generation {generation:?}");
    }
    let m2 = match m2 {
        Some(m) => m,
        None => gen_filter.best()?,
    };
    let m1 = match m1 {
        Some(m) => m,
        None => {
            // Cheapest model that is still reasonably capable.
            PoolFilter {
                generation: Some(generation),
                min_capability: Some(0.5),
                ..Default::default()
            }
            .cheapest()?
        }
    };
    let verifier = match verifier {
        Some(m) => m,
        None => {
            // Verifier must not cost more than m1 (blended price heuristic);
            // fall back to m1 itself when nothing cheaper qualifies.
            let limit = m1.spec().usd_per_mtok_in;
            PoolFilter {
                generation: Some(generation),
                max_usd_per_mtok_in: Some(limit),
                min_capability: Some(0.55),
                ..Default::default()
            }
            .best()
            .unwrap_or(m1)
        }
    };
    Ok((m1, m2, verifier))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filter_by_price() {
        let cheap = PoolFilter {
            max_usd_per_mtok_in: Some(0.3),
            ..Default::default()
        }
        .select();
        assert!(!cheap.is_empty());
        assert!(cheap.iter().all(|m| m.usd_per_mtok_in <= 0.3));
        assert!(!cheap.iter().any(|m| m.id == ModelId::Gpt4));
    }

    #[test]
    fn cheapest_and_best() {
        let all = PoolFilter::default();
        let cheapest = all.cheapest().unwrap();
        assert!(matches!(
            cheapest,
            ModelId::Phi3Mini | ModelId::Gemini20Flash
        ));
        assert_eq!(all.best().unwrap(), ModelId::SonarHugeOnline);
    }

    #[test]
    fn empty_filter_errors() {
        let none = PoolFilter {
            min_capability: Some(2.0),
            ..Default::default()
        };
        assert!(none.cheapest().is_err());
    }

    #[test]
    fn default_cascade_old_generation() {
        let (m1, m2, v) =
            cascade_models(Generation::Old, None, None, None).unwrap();
        assert_eq!(m1, ModelId::Gpt35Turbo);
        assert_eq!(m2, ModelId::Gpt4);
        // Verifier at most as expensive as m1 (or m1 itself).
        assert!(v.spec().usd_per_mtok_in <= m1.spec().usd_per_mtok_in);
    }

    #[test]
    fn paper_configs_respected_when_pinned() {
        // §5.3 old setup: M1=GPT-3.5, M2=GPT-4, verifier=Claude Opus.
        let (m1, m2, v) = cascade_models(
            Generation::Old,
            Some(ModelId::Gpt35Turbo),
            Some(ModelId::Gpt4),
            Some(ModelId::Claude3Opus),
        )
        .unwrap();
        assert_eq!(
            (m1, m2, v),
            (ModelId::Gpt35Turbo, ModelId::Gpt4, ModelId::Claude3Opus)
        );
    }

    #[test]
    fn allowed_list_restricts() {
        let f = PoolFilter {
            allowed: Some(vec![ModelId::Phi3Mini, ModelId::Gpt4oMini]),
            ..Default::default()
        };
        let picks = f.select();
        assert_eq!(picks.len(), 2);
    }
}
