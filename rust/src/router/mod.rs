//! The model router: every model-choice decision in the proxy, as data.
//!
//! The coordinator used to make model choices in four places — a
//! `pick_model` match, an `escalate` match, the cascade arm of `resolve`,
//! and the context-filter match — so adding a service type meant touching
//! all of them. Here a [`ServiceType`](crate::api::ServiceType) *lowers*
//! to a declarative [`ServicePolicy`]:
//!
//! * which caches to consult ([`CachePlan`]),
//! * which context filter to run ([`Filter`]),
//! * how to choose the answering model(s) ([`RoutingPolicy`]),
//! * whether the per-user quota gates/charges the request.
//!
//! The pipeline stages execute whatever the policy says; they never
//! inspect the service type. Adding a service type is one lowering entry
//! (plus, optionally, an [`escalate`] nudge) — see ROADMAP.md
//! §Architecture.
//!
//! Routing policies are *scored over the pool*: each strategy is a
//! deterministic argmin/argmax over [`POOL`](crate::models::pricing::POOL)
//! columns (price, capability, latency class, decode budget), using the
//! scoring helpers in [`crate::models::pricing`].

pub mod filter;

pub use filter::{cascade_models, PoolFilter};

use std::fmt;

use crate::api::{CachePolicy, ServiceType};
use crate::context::Filter;
use crate::models::pricing::{
    cheapest_in, flagship, priciest_in, Generation, LatencyClass, ModelId, POOL,
};

/// Cache participation for one request (regeneration always bypasses both
/// lookups; that rule lives in the cache stage, not the plan).
#[derive(Clone, Debug, PartialEq)]
pub struct CachePlan {
    /// Consult the exact-match prefetch store (§5.1 buttons).
    pub exact: bool,
    /// Delegated semantic GET grounded by this cache-LLM (§3.5).
    pub smart: Option<ModelId>,
}

/// How the answering model(s) are chosen. Every variant is a pure
/// function of the pool table plus the request's `model` param.
#[derive(Clone, Debug, PartialEq)]
pub enum RoutingPolicy {
    /// Always this model.
    Fixed(ModelId),
    /// Cheapest model by input price within a generation (§3.2 "cost").
    CostMin(Generation),
    /// Most expensive model by input price within a generation (§3.2
    /// "quality" — the paper's proxy for best).
    QualityMax(Generation),
    /// Most capable model whose input price is at or under a USD/Mtok
    /// ceiling; a ceiling no pool model satisfies rejects the request
    /// (a cost-control policy must never silently overspend).
    BudgetCap {
        generation: Generation,
        max_usd_per_mtok_in: f64,
    },
    /// Fastest model in a latency class: smallest decode budget
    /// (`default_max_new`), ties broken by capability.
    LatencyClass(LatencyClass),
    /// Curated model list (§5.2): the requested model if allowed, else the
    /// fallback. Pairs with `ServicePolicy::quota`.
    Allowlist {
        allowed: Vec<ModelId>,
        fallback: ModelId,
    },
    /// Verification cascade (§3.3): unpinned roles resolved over the pool
    /// at route time by [`cascade_models`].
    CascadeVerify {
        generation: Generation,
        threshold: f64,
        m1: Option<ModelId>,
        m2: Option<ModelId>,
        verifier: Option<ModelId>,
    },
}

/// A routed request: either one model answers, or the cascade runs.
#[derive(Clone, Debug, PartialEq)]
pub enum RoutePlan {
    Single {
        model: ModelId,
        /// The caller asked for an off-list model and was re-routed to the
        /// fallback (the §5.2 "curated list" deny).
        denied_requested: bool,
    },
    Cascade {
        m1: ModelId,
        m2: ModelId,
        verifier: ModelId,
        threshold: f64,
    },
}

impl RoutePlan {
    fn single(model: ModelId) -> RoutePlan {
        RoutePlan::Single {
            model,
            denied_requested: false,
        }
    }
}

/// Why a policy could not produce a plan.
#[derive(Debug)]
pub enum RouteError {
    /// The request named a model the pool does not know.
    UnknownModel(String),
    /// The caller's price ceiling is below every pool model.
    NoModelUnderBudget { max_usd_per_mtok_in: f64 },
    /// No pool entry satisfies the policy (named for diagnostics).
    EmptyPool(&'static str),
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::UnknownModel(m) => write!(f, "unknown model id '{m}'"),
            RouteError::NoModelUnderBudget { max_usd_per_mtok_in } => write!(
                f,
                "no pool model costs <= ${max_usd_per_mtok_in}/Mtok input"
            ),
            RouteError::EmptyPool(policy) => {
                write!(f, "no pool model satisfies the {policy} policy")
            }
        }
    }
}

impl std::error::Error for RouteError {}

impl RoutingPolicy {
    /// Score the policy over the pool. `requested_model` is the request's
    /// `model` param (only the allowlist policy reads it).
    pub fn route(&self, requested_model: Option<&str>) -> Result<RoutePlan, RouteError> {
        Ok(match self {
            RoutingPolicy::Fixed(m) => RoutePlan::single(*m),
            RoutingPolicy::CostMin(g) => RoutePlan::single(
                cheapest_in(*g).ok_or(RouteError::EmptyPool("cost-min"))?,
            ),
            RoutingPolicy::QualityMax(g) => RoutePlan::single(
                priciest_in(*g).ok_or(RouteError::EmptyPool("quality-max"))?,
            ),
            RoutingPolicy::BudgetCap {
                generation,
                max_usd_per_mtok_in,
            } => RoutePlan::single(
                PoolFilter {
                    generation: Some(*generation),
                    max_usd_per_mtok_in: Some(*max_usd_per_mtok_in),
                    ..Default::default()
                }
                .best()
                .ok()
                .ok_or(RouteError::NoModelUnderBudget {
                    max_usd_per_mtok_in: *max_usd_per_mtok_in,
                })?,
            ),
            RoutingPolicy::LatencyClass(class) => {
                let in_class = || POOL.iter().filter(|m| m.latency_class == *class);
                let floor = in_class()
                    .map(|m| m.default_max_new)
                    .min()
                    .ok_or(RouteError::EmptyPool("latency-class"))?;
                RoutePlan::single(
                    in_class()
                        .filter(|m| m.default_max_new == floor)
                        .max_by(|a, b| a.capability.partial_cmp(&b.capability).unwrap())
                        .map(|m| m.id)
                        .expect("floor came from a non-empty class"),
                )
            }
            RoutingPolicy::Allowlist { allowed, fallback } => match requested_model {
                Some(name) => {
                    let wanted = ModelId::parse(name)
                        .map_err(|_| RouteError::UnknownModel(name.to_string()))?;
                    if allowed.contains(&wanted) {
                        RoutePlan::single(wanted)
                    } else {
                        RoutePlan::Single {
                            model: *fallback,
                            denied_requested: true,
                        }
                    }
                }
                None => RoutePlan::single(*fallback),
            },
            RoutingPolicy::CascadeVerify {
                generation,
                threshold,
                m1,
                m2,
                verifier,
            } => {
                let (m1, m2, verifier) = cascade_models(*generation, *m1, *m2, *verifier)
                    .map_err(|_| RouteError::EmptyPool("cascade-with-verifier"))?;
                RoutePlan::Cascade {
                    m1,
                    m2,
                    verifier,
                    threshold: *threshold,
                }
            }
        })
    }
}

/// Everything the pipeline needs to serve one service type: the lowered,
/// declarative form of [`ServiceType`].
#[derive(Clone, Debug, PartialEq)]
pub struct ServicePolicy {
    pub cache: CachePlan,
    pub context: Filter,
    pub routing: RoutingPolicy,
    /// Gate the request on (and charge it against) the per-user quota.
    pub quota: bool,
}

impl ServicePolicy {
    fn new(cache: CachePlan, context: Filter, routing: RoutingPolicy) -> ServicePolicy {
        ServicePolicy {
            cache,
            context,
            routing,
            quota: false,
        }
    }
}

const EXACT_ONLY: CachePlan = CachePlan {
    exact: true,
    smart: None,
};

/// Lower a service type to its policy. This is the single place a service
/// type's semantics are defined; the coordinator stages execute the
/// policy blindly.
///
/// # Adding a service type
///
/// Three steps — the coordinator, server, and pipeline stages need no
/// changes, because stages never inspect [`ServiceType`]:
///
/// 1. **Declare it**: add a variant to [`ServiceType`](crate::api::ServiceType)
///    and wire its JSON name/params into `ServiceType::from_json`/`to_json`
///    (the REST representation is `{"name": ..., params...}`).
/// 2. **Lower it**: add one match arm here picking a [`CachePlan`], a
///    context [`Filter`], and a [`RoutingPolicy`] — reuse an existing
///    policy or add a new scored variant (a deterministic argmin/argmax
///    over [`POOL`](crate::models::pricing::POOL) columns), and set
///    `quota` if the per-user gate should apply.
/// 3. **Optionally escalate it**: add an arm to [`escalate`] if
///    regeneration should nudge the type toward quality (§3.2); the
///    default keeps the same type.
///
/// Worked example — `ServiceType::Budget` ("best model under $X/Mtok
/// input", added as the policy-extension proof in PR 2): step 1 added the
/// variant with a `max_usd_per_mtok_in` param; step 2 is the
/// `ServiceType::Budget` arm below lowering to
/// [`RoutingPolicy::BudgetCap`] (which rejects an impossible ceiling with
/// a typed [`RouteError::NoModelUnderBudget`] rather than silently
/// overspending — a cost-control policy must never overspend); step 3
/// regenerates as `Quality`, dropping the ceiling. The parity table in
/// `rust/tests/router_policies.rs` locks each type's lowering + picks.
pub fn lower(st: &ServiceType, generation: Generation, regen_count: u32) -> ServicePolicy {
    match st {
        ServiceType::Fixed {
            model,
            cache,
            context_k,
        } => ServicePolicy::new(
            CachePlan {
                exact: *cache != CachePolicy::Skip,
                smart: None,
            },
            Filter::LastK(*context_k),
            RoutingPolicy::Fixed(*model),
        ),
        ServiceType::Quality => ServicePolicy::new(
            EXACT_ONLY,
            Filter::All,
            RoutingPolicy::QualityMax(generation),
        ),
        ServiceType::Cost => ServicePolicy::new(
            EXACT_ONLY,
            Filter::None,
            RoutingPolicy::CostMin(generation),
        ),
        ServiceType::Budget { max_usd_per_mtok_in } => ServicePolicy::new(
            EXACT_ONLY,
            Filter::None,
            RoutingPolicy::BudgetCap {
                generation,
                max_usd_per_mtok_in: *max_usd_per_mtok_in,
            },
        ),
        ServiceType::ModelSelector {
            threshold,
            m1,
            m2,
            verifier,
        } => ServicePolicy::new(
            EXACT_ONLY,
            // §3.2: model_selector "uses 5 previous messages as context".
            Filter::LastK(5),
            RoutingPolicy::CascadeVerify {
                generation,
                threshold: *threshold,
                m1: *m1,
                m2: *m2,
                verifier: *verifier,
            },
        ),
        ServiceType::SmartContext { k, model } => ServicePolicy::new(
            EXACT_ONLY,
            if regen_count > 0 {
                // Regeneration nudges toward quality: full last-k.
                Filter::LastK(*k)
            } else {
                Filter::smart_last_k(*k, *model)
            },
            RoutingPolicy::Fixed(flagship(generation)),
        ),
        ServiceType::SmartCache { model } => ServicePolicy::new(
            CachePlan {
                exact: true,
                smart: Some(*model),
            },
            Filter::None,
            RoutingPolicy::Fixed(*model),
        ),
        ServiceType::UsageBased { allowed, fallback } => {
            let mut p = ServicePolicy::new(
                EXACT_ONLY,
                Filter::LastK(3),
                RoutingPolicy::Allowlist {
                    allowed: allowed.clone(),
                    fallback: *fallback,
                },
            );
            p.quota = true;
            p
        }
        ServiceType::LatencyFirst => ServicePolicy::new(
            EXACT_ONLY,
            Filter::LastK(1),
            RoutingPolicy::LatencyClass(LatencyClass::Small),
        ),
    }
}

/// Same-service-type regeneration: "nudge the proxy to prioritize quality
/// over cost" (§3.2).
pub fn escalate(st: &ServiceType, generation: Generation) -> ServiceType {
    let big = flagship(generation);
    match st {
        // §3.3: "regenerate will directly route the prompt to the more
        // expensive LLM".
        ServiceType::ModelSelector { m2, .. } => ServiceType::Fixed {
            model: m2.unwrap_or(big),
            cache: CachePolicy::Skip,
            context_k: 5,
        },
        // §3.2: "for smart_context, regenerating entails using more
        // context".
        ServiceType::SmartContext { k, .. } => ServiceType::Fixed {
            model: big,
            cache: CachePolicy::Skip,
            context_k: (*k).max(5),
        },
        ServiceType::SmartCache { .. } => ServiceType::ModelSelector {
            threshold: 8.0,
            m1: None,
            m2: None,
            verifier: None,
        },
        ServiceType::Cost => ServiceType::Quality,
        // A budget request regenerates without the price ceiling.
        ServiceType::Budget { .. } => ServiceType::Quality,
        ServiceType::LatencyFirst => ServiceType::Fixed {
            model: big,
            cache: CachePolicy::Skip,
            context_k: 5,
        },
        other => other.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escalate_model_selector_goes_direct_m2() {
        let st = ServiceType::ModelSelector {
            threshold: 8.0,
            m1: None,
            m2: Some(ModelId::Gpt4),
            verifier: None,
        };
        match escalate(&st, Generation::Old) {
            ServiceType::Fixed { model, .. } => assert_eq!(model, ModelId::Gpt4),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn escalate_smart_context_adds_context() {
        let st = ServiceType::SmartContext {
            k: 1,
            model: ModelId::Claude3Haiku,
        };
        match escalate(&st, Generation::New) {
            ServiceType::Fixed {
                model, context_k, ..
            } => {
                assert_eq!(model, ModelId::Gpt4o);
                assert_eq!(context_k, 5);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn escalate_cost_and_budget_become_quality() {
        assert_eq!(escalate(&ServiceType::Cost, Generation::New), ServiceType::Quality);
        assert_eq!(
            escalate(&ServiceType::Budget { max_usd_per_mtok_in: 1.0 }, Generation::New),
            ServiceType::Quality
        );
    }

    #[test]
    fn latency_class_scores_decode_budget_then_capability() {
        // Small class decode-budget floor is 10 tokens (Haiku, Phi-3);
        // Haiku wins the capability tie-break — matching the §5.1
        // deployment's hardcoded latency-first model.
        let plan = RoutingPolicy::LatencyClass(LatencyClass::Small)
            .route(None)
            .unwrap();
        assert_eq!(plan, RoutePlan::single(ModelId::Claude3Haiku));
    }

    #[test]
    fn budget_cap_picks_best_under_ceiling() {
        let plan = |cap: f64| {
            RoutingPolicy::BudgetCap {
                generation: Generation::New,
                max_usd_per_mtok_in: cap,
            }
            .route(None)
        };
        // Under $1/Mtok the most capable new-gen model is Gemini Flash.
        assert_eq!(plan(1.0).unwrap(), RoutePlan::single(ModelId::Gemini20Flash));
        // Under $3 the flagship 4o fits.
        assert_eq!(plan(3.0).unwrap(), RoutePlan::single(ModelId::Gpt4o));
        // An impossible budget is rejected, never silently overspent.
        assert!(matches!(
            plan(0.01),
            Err(RouteError::NoModelUnderBudget { .. })
        ));
    }

    #[test]
    fn allowlist_denies_and_falls_back() {
        let policy = RoutingPolicy::Allowlist {
            allowed: vec![ModelId::Gpt4oMini, ModelId::Phi3Mini],
            fallback: ModelId::Gpt4oMini,
        };
        assert_eq!(
            policy.route(Some("phi-3-mini")).unwrap(),
            RoutePlan::single(ModelId::Phi3Mini)
        );
        assert_eq!(
            policy.route(Some("gpt-4")).unwrap(),
            RoutePlan::Single {
                model: ModelId::Gpt4oMini,
                denied_requested: true
            }
        );
        assert_eq!(
            policy.route(None).unwrap(),
            RoutePlan::single(ModelId::Gpt4oMini)
        );
        assert!(matches!(
            policy.route(Some("gpt-99")),
            Err(RouteError::UnknownModel(_))
        ));
    }

    #[test]
    fn smart_cache_plan_is_regen_independent() {
        // The universal regen cache bypass lives in the cache stage; the
        // plan itself does not change with regen_count.
        let st = ServiceType::SmartCache {
            model: ModelId::Phi3Mini,
        };
        for regen in [0, 1] {
            assert_eq!(
                lower(&st, Generation::New, regen).cache.smart,
                Some(ModelId::Phi3Mini)
            );
        }
    }

    #[test]
    fn fixed_skip_bypasses_exact_cache() {
        let st = ServiceType::Fixed {
            model: ModelId::Gpt4oMini,
            cache: CachePolicy::Skip,
            context_k: 2,
        };
        let p = lower(&st, Generation::New, 0);
        assert!(!p.cache.exact);
        assert_eq!(p.context, Filter::LastK(2));
        assert_eq!(p.routing, RoutingPolicy::Fixed(ModelId::Gpt4oMini));
        assert!(!p.quota);
    }
}
