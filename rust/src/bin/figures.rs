//! `figures` — regenerate every table and figure of the paper's evaluation.
//!
//! ```text
//! figures --all                 # everything (slow: full 244-query replays)
//! figures --fig 1a|1b|4a|4b|5a|5b|6a|6b|6c|7a|7b
//! figures --table 3             # context-filter grammar resolution
//! figures --queries 60          # subsample for a quick pass
//! figures --artifacts DIR --seed N
//! ```
//!
//! Output is the same rows/series the paper plots; EXPERIMENTS.md records a
//! full run against the paper's numbers.

use anyhow::Result;

use llmbridge::context::Filter;
use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::experiments as exp;
use llmbridge::models::pricing::{Generation, ModelId};
use llmbridge::util::cli::Args;

const CDF_PS: &[f64] = &[0.01, 0.05, 0.10, 0.20, 0.50, 0.80, 0.95];

fn print_cdf(label: &str, scores: &[f64]) {
    let ps = exp::percentiles(scores.to_vec(), CDF_PS);
    let cells: Vec<String> = ps
        .iter()
        .map(|(p, v)| format!("p{:02.0}={v:.2}", p * 100.0))
        .collect();
    println!(
        "  {label:<28} mean={:.2}  {}",
        exp::mean(scores),
        cells.join("  ")
    );
}

struct Ctx {
    engine: llmbridge::runtime::EngineHandle,
    seed: u64,
    limit: Option<usize>,
}

impl Ctx {
    fn bridge(&self, generation: Generation) -> Result<Bridge> {
        Bridge::from_engine(
            self.engine.clone(),
            BridgeConfig {
                generation,
                ..Default::default()
            },
        )
    }
}

fn fig1(cx: &Ctx, which: &str) -> Result<()> {
    let bridge = cx.bridge(Generation::New)?;
    let rows = exp::fig1(&bridge, cx.seed, cx.limit)?;
    if which != "1b" {
        println!("\n== Fig 1a: input tokens vs last-k (50-query conversation) ==");
        println!("  (paper: k=50 uses ~55x the input tokens of k=0; k=1 ~3x; growth is O(n^2))");
        let base = rows[0].input_tokens.max(1);
        for r in &rows {
            println!(
                "  k={:<3} input_tokens={:>8}  x{:.1} of k=0  cost=${:.4}",
                r.k,
                r.input_tokens,
                r.input_tokens as f64 / base as f64,
                r.cost_usd
            );
        }
    }
    if which != "1a" {
        println!("\n== Fig 1b: response quality CDF vs k (reference: k=50) ==");
        println!("  (paper: no-context is worst, difference concentrated in tail 20%)");
        for r in &rows {
            print_cdf(&format!("last-{}", r.k), &r.quality_scores);
        }
    }
    Ok(())
}

fn fig45(cx: &Ctx, which: &str) -> Result<()> {
    // 4a + 5a/5b use old models per the paper; 4b uses new.
    let generation = if which == "4b" { Generation::New } else { Generation::Old };
    let bridge = cx.bridge(generation)?;
    let out = exp::fig45(&bridge, cx.seed, generation, cx.limit)?;
    let (m1, m2, v) = exp::fig45_models(generation);
    let print_quality = matches!(which, "4a" | "4b" | "45");
    let print_cost_time = matches!(which, "5a" | "5b" | "45");
    if print_quality {
            println!(
                "\n== Fig {which}: model-selection quality CDF ({generation:?} models: M1={m1}, M2={m2}, verifier={v}) =="
            );
            println!(
                "  escalation: verifier t=8 routed {:.0}% of prompts to M2 (paper: {}%)",
                out.escalation_fraction * 100.0,
                if generation == Generation::Old { ">60" } else { "~25" },
            );
            for (label, scores) in &out.quality {
                print_cdf(label, scores);
            }
    }
    if print_cost_time {
            println!("\n== Fig 5a: total cost, normalized to M1-only ({generation:?} models) ==");
            println!("  (paper: verification is ~40% cheaper than M2-only)");
            for (label, c) in &out.cost {
                println!("  {label:<28} cost x{c:.2}");
            }
            let verify_cost = out.cost.iter().find(|(l, _)| l.starts_with("verification")).unwrap().1;
            let m2_cost = out.cost.last().unwrap().1;
            println!(
                "  -> verification / M2-only = {:.2} ({:.0}% reduction)",
                verify_cost / m2_cost,
                (1.0 - verify_cost / m2_cost) * 100.0
            );
            println!("\n== Fig 5b: total LLM time, normalized to M1-only ==");
            println!("  (paper: verification ~5x M1-only, well under M2-only)");
            for (label, t) in &out.time {
                println!("  {label:<28} time x{t:.2}");
            }
    }
    Ok(())
}

fn fig6(cx: &Ctx, which: &str) -> Result<()> {
    let bridge = cx.bridge(Generation::New)?;
    let out = exp::fig6(&bridge, cx.seed, cx.limit)?;
    if which == "6a" || which == "6" {
        println!("\n== Fig 6a: context strategies, cost normalized (cheapest = 1) ==");
        println!("  (paper: smart+k1 ~30% and smart+k5 ~50% cheaper than their last-k)");
        for (label, c) in &out.cost {
            println!("  {label:<28} cost x{c:.2}");
        }
    }
    if which == "6b" || which == "6" {
        println!("\n== Fig 6b: quality CDF vs LastK(5) reference ==");
        println!("  (paper: smart strategies fall between k=0 and k=1; tail-20% effect)");
        for (label, scores) in &out.quality {
            print_cdf(label, scores);
        }
    }
    if which == "6c" || which == "6" {
        println!("\n== Fig 6c: fraction of LLM time spent deciding (SmartContext call) ==");
        println!("  (paper: <20% of total time for ~80% of messages; max <50%)");
        for (label, fracs) in &out.decision_time_fraction {
            print_cdf(label, fracs);
        }
    }
    Ok(())
}

fn fig7(cx: &Ctx, which: &str) -> Result<()> {
    let bridge = cx.bridge(Generation::New)?;
    let out = exp::fig7(&bridge, cx.seed, cx.limit)?;
    println!(
        "\n  factual queries: {}  |  smart_cache used cached content on {}",
        out.n_factual, out.n_cache_used
    );
    if which == "7a" || which == "7" {
        println!("\n== Fig 7a: quality CDF on factual queries (reference: sonar-huge-online) ==");
        println!("  (paper: GPT-4o >> Phi-3; smart_cache lifts the worst 20%, 4x worst-case)");
        for (label, scores) in &out.quality {
            print_cdf(label, scores);
        }
    }
    if which == "7b" || which == "7" {
        println!("\n== Fig 7b: subset where smart_cache used the cache ==");
        println!("  (paper: min score 4 with cache vs 1 with Phi-3 alone)");
        for (label, scores) in &out.cache_used_quality {
            let min = scores.iter().cloned().fold(f64::INFINITY, f64::min);
            print_cdf(label, scores);
            println!("    min score: {min:.2}");
        }
    }
    Ok(())
}

fn ablation(cx: &Ctx) -> Result<()> {
    let bridge = cx.bridge(Generation::Old)?;
    println!("\n== Ablation: verifier threshold sweep (old models, D) ==");
    let limit = cx.limit.or(Some(80));
    let rows = exp::ablation_threshold(&bridge, cx.seed, &[6.0, 7.0, 8.0, 9.0], limit)?;
    println!("  {:<6} {:>11} {:>13} {:>11}", "t", "escalation", "mean quality", "cost/M2");
    for r in &rows {
        println!(
            "  t={:<4} {:>10.0}% {:>13.2} {:>11.2}",
            r.threshold,
            r.escalation * 100.0,
            r.mean_quality,
            r.cost_vs_m2
        );
    }
    println!("\n== Ablation: SmartContext single vs double classifier call ==");
    for cap in [0.45, 0.60, 0.78] {
        let (one, two) = exp::smart_context_false_positive_rates(cap);
        println!(
            "  context-LLM capability {cap:.2}: false-positive rate {one:.3} (1 call) -> {two:.3} (2 calls)"
        );
    }
    Ok(())
}

fn table3() {
    println!("\n== Table 3: context filter grammar (resolved plans) ==");
    let rows: Vec<(&str, Filter)> = vec![
        (
            "SmartContext(LLM)",
            Filter::SmartContext {
                model: ModelId::Claude3Haiku,
            },
        ),
        (
            "[LastK(5), SmartContext]",
            Filter::smart_last_k(5, ModelId::Claude3Haiku),
        ),
        (
            "[[LastK(4), SmartContext], LastK(1)]",
            Filter::smart_with_floor(5, ModelId::Claude3Haiku),
        ),
        (
            "Similar(0.5)",
            Filter::Similar {
                threshold: 0.5,
                max: 5,
            },
        ),
        (
            "Summarize(LLM)",
            Filter::Summarize {
                model: ModelId::Claude3Haiku,
            },
        ),
    ];
    for (name, f) in rows {
        println!("  {name:<40} => {f:?}");
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cx = Ctx {
        engine: llmbridge::runtime::EngineHandle::spawn_from_dir(
            args.get_or("artifacts", "artifacts"),
        )?,
        seed: args.u64_or("seed", exp::DEFAULT_SEED),
        limit: args.get("queries").and_then(|q| q.parse().ok()),
    };

    let all = args.flag("all") || (args.get("fig").is_none() && args.get("table").is_none());
    if let Some(t) = args.get("table") {
        if t == "3" {
            table3();
        }
    }
    if args.flag("ablation") {
        ablation(&cx)?;
    }
    let figs: Vec<String> = if all {
        // Each experiment computed once: "1" prints 1a+1b, "45" prints
        // 4a+5a+5b, "4b" the new-generation quality CDF, "6" all of 6a-c,
        // "7" both cache panels.
        ["1", "45", "4b", "6", "7"].iter().map(|s| s.to_string()).collect()
    } else {
        args.get("fig").map(|f| vec![f.to_string()]).unwrap_or_default()
    };
    for f in &figs {
        let t0 = std::time::Instant::now();
        match f.as_str() {
            "1" | "1a" | "1b" => fig1(&cx, f)?,
            "45" | "4a" | "4b" | "5a" | "5b" => fig45(&cx, f)?,
            "6" | "6a" | "6b" | "6c" => fig6(&cx, f)?,
            "7" | "7a" | "7b" => fig7(&cx, f)?,
            other => eprintln!("unknown figure '{other}'"),
        }
        eprintln!("  [fig {f} took {:.1?}]", t0.elapsed());
    }
    if all {
        table3();
    }
    Ok(())
}
