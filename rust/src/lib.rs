//! # LLMBridge
//!
//! A cost-optimizing LLM **proxy** for a prompt-centric Internet — a
//! production-shaped reproduction of *"LLMBridge: Reducing Costs to Access
//! LLMs in a Prompt-Centric Internet"* (Martin et al., 2024).
//!
//! LLMBridge sits between applications and a pool of LLMs and applies three
//! cost optimizations, each delegable to a low-cost model:
//!
//! * **Model selection** ([`adapter`]) — a verification-based cascade: a
//!   cheap model answers, a verifier LLM scores the answer, and the
//!   expensive model is consulted only when the score falls below a
//!   threshold (§3.3 of the paper).
//! * **Context management** ([`context`]) — a filter pipeline over the
//!   conversation history (`LastK`, `SmartContext`, `Similar`, `Summarize`
//!   per Table 3), including a small-model classifier that decides whether
//!   context is needed at all (§3.4).
//! * **Semantic caching** ([`cache`]) — a typed-key semantic cache over a
//!   vector database, with *delegated* PUT (chunking + key generation via a
//!   cache-LLM) and *delegated* GET ("SmartCache") that grounds a local
//!   model's answer in cached facts (§3.5). With a data directory
//!   configured, the [`persist`] subsystem (snapshot + WAL) makes the
//!   cache, quotas, and exchanges durable to the last write — restarts
//!   never re-pay the API cost the cache exists to avoid — while
//!   conversation history restores from the last snapshot compaction.
//!
//! Applications drive these through the high-level, **bidirectional** API
//! ([`api`]): a `service_type` per request delegates decisions to the proxy,
//! response metadata makes every decision transparent, and
//! `regenerate` supports iterative refinement.
//!
//! ## Architecture (three layers, Python never on the request path)
//!
//! ```text
//!  L3  this crate       the proxy: API, staged coordinator pipeline,
//!                       policy router, adapter, context manager, semantic
//!                       cache, FIFO queues, REST server, telemetry,
//!                       workload generators
//!  L2  python/compile/  JAX transformer pool + embedder (build time)
//!  L1  python/.../kernels  Pallas attention + matmul (build time)
//!  RT  [`runtime`]      pluggable inference backend behind one engine
//!                       thread: pure-Rust deterministic (default) or the
//!                       PJRT client executing artifacts/*.hlo.txt
//!                       (`--features pjrt`)
//! ```
//!
//! The "LLMs" are either the default build's deterministic pure-Rust
//! stand-ins or, under `--features pjrt`, AOT-compiled JAX/Pallas
//! transformer artifacts executed via PJRT — same geometry, same
//! tokenizer, same engine-thread RPC (see [`runtime::backend`]). Response
//! *quality* is simulated by a calibrated latent model
//! ([`models::quality`]) in both cases, because tiny random-weight LMs
//! have no meaningful quality ordering — see DESIGN.md §Substitutions.

pub mod adapter;
pub mod api;
pub mod cache;
pub mod context;
pub mod coordinator;
pub mod error;
pub mod experiments;
pub mod kvstore;
pub mod models;
pub mod ops;
pub mod persist;
pub mod queuing;
pub mod router;
pub mod runtime;
pub mod scenario;
pub mod server;
pub mod sync;
pub mod telemetry;
pub mod util;
pub mod vecdb;
pub mod workload;

/// Convenient re-exports for applications.
pub mod prelude {
    pub use crate::api::{Metadata, Request, Response, ServiceType};
    pub use crate::coordinator::Bridge;
    pub use crate::error::BridgeError;
    pub use crate::models::pricing::{ModelId, POOL};
    pub use crate::router::{RoutingPolicy, ServicePolicy};
    pub use crate::workload::whatsapp::WhatsAppWorkload;
}
