//! Bench for Fig 6 (SmartContext): cost per strategy, quality vs LastK(5),
//! and the share of time spent on the context-LLM decision call.

mod bench_common;

use llmbridge::experiments as exp;
use llmbridge::models::pricing::Generation;
use llmbridge::util::bench::bench;

fn main() {
    let bridge = bench_common::bridge(Generation::New);
    let limit = bench_common::query_limit();
    let mut out = None;
    bench("fig6/replay_context_strategies", 0, 1, || {
        out = Some(exp::fig6(&bridge, exp::DEFAULT_SEED, limit).unwrap());
    });
    let out = out.unwrap();

    println!("\nFig 6a — cost normalized, cheapest = 1 (paper: smart ~30-50% under last-k):");
    for (label, c) in &out.cost {
        println!("  {label:<24} x{c:.2}");
    }
    println!("\nFig 6b — quality vs LastK(5) reference:");
    for (label, scores) in &out.quality {
        let ps = exp::percentiles(scores.clone(), &[0.05, 0.2, 0.5]);
        println!(
            "  {label:<24} mean={:.2} p05={:.2} p20={:.2} p50={:.2}",
            exp::mean(scores),
            ps[0].1,
            ps[1].1,
            ps[2].1
        );
    }
    println!("\nFig 6c — fraction of LLM time in the SmartContext decision:");
    println!("  (paper: <20% for ~80% of messages; max <50%)");
    for (label, fracs) in &out.decision_time_fraction {
        let ps = exp::percentiles(fracs.clone(), &[0.5, 0.8, 1.0]);
        println!(
            "  {label:<24} p50={:.2} p80={:.2} max={:.2}",
            ps[0].1, ps[1].1, ps[2].1
        );
    }
}
