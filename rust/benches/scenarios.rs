//! Open-loop scenario matrix bench: runs every scenario in
//! `scenario::default_matrix` against the real HTTP server and writes
//! per-scenario results (p50/p99 measured from the *scheduled* arrival,
//! cost per 1k requests, cache hit rate, shed rate by reason, SLO
//! violations during the reconfiguration cutover window, the
//! old-or-new-snapshot invariant tally) to the path in
//! `LLMBRIDGE_BENCH_JSON` — `scripts/bench.sh` lands it in
//! `BENCH_scenarios.json` (ROADMAP.md §Perf trajectory).
//!
//! `LLMBRIDGE_BENCH_SMOKE=1` shrinks to the reduced corpus the CI gate
//! (`tests/scenarios.rs`) uses; full mode runs 5-second legs with up to
//! 4000 events per scenario. Load levels are multiples of a calibrated
//! closed-loop capacity, so the matrix stresses a laptop and a CI runner
//! by the same *relative* amounts.

mod bench_common;

use llmbridge::scenario::{default_matrix, run_matrix, RunOptions};
use llmbridge::server::ServerBackend;
use llmbridge::util::bench::{smoke_mode, BenchReport};
use llmbridge::util::json::Json;

fn main() {
    let engine = bench_common::engine();
    let backend = if cfg!(target_os = "linux") {
        ServerBackend::Evented
    } else {
        ServerBackend::Threaded
    };
    let opts = RunOptions::new(backend, smoke_mode());

    let outcomes = run_matrix(&engine, &default_matrix(), &opts).expect("scenario matrix");

    let mut report = BenchReport::new();
    for o in &outcomes {
        println!(
            "scenario {:<14} offered {:>7.0} req/s  served {:>5}  shed {:>5} ({:>4.1}%)  \
             p50 {:>7} us  p99 {:>7} us  cost/1k ${:>8.4}  hit {:>5.1}%",
            o.name,
            o.offered_rps,
            o.served,
            o.shed,
            o.shed_rate() * 100.0,
            o.p50_us,
            o.p99_us,
            o.cost_per_1k_usd,
            o.cache_hit_rate * 100.0
        );
        if let Some(inv) = &o.invariant {
            println!(
                "scenario {:<14} invariant: checked {} old {} new {} cache {} mixed {}",
                o.name, inv.checked, inv.old_only, inv.new_only, inv.cache_only, inv.mixed
            );
            assert_eq!(inv.mixed, 0, "half-applied config observed under load");
        }
        report.push(&format!("scenarios/{}", o.name), o.to_json());
    }
    report.push(
        "scenarios/meta",
        Json::obj(vec![
            ("smoke", Json::Bool(smoke_mode())),
            (
                "backend",
                Json::str(if matches!(backend, ServerBackend::Evented) {
                    "evented"
                } else {
                    "threaded"
                }),
            ),
            ("count", Json::num(outcomes.len() as f64)),
        ]),
    );
    report.write_env("LLMBRIDGE_BENCH_JSON");

    engine.shutdown();
}
