//! Shared bench harness setup.

use std::sync::Arc;

use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::Generation;
use llmbridge::runtime::EngineHandle;

pub fn engine() -> EngineHandle {
    // Deterministic backend on the default build; PJRT over the AOT
    // artifacts under `--features pjrt` (then run `make artifacts` first).
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    EngineHandle::spawn_from_dir(dir).expect("bring up serving backend")
}

pub fn bridge(generation: Generation) -> Arc<Bridge> {
    Arc::new(
        Bridge::from_engine(
            engine(),
            BridgeConfig {
                generation,
                ..Default::default()
            },
        )
        .unwrap(),
    )
}

/// Query budget for replay benches: small by default so `cargo bench`
/// finishes quickly; the `figures` binary regenerates the full-dataset
/// numbers.
#[allow(dead_code)] // each bench target compiles its own copy; not all use it
pub fn query_limit() -> Option<usize> {
    if std::env::var("LLMBRIDGE_BENCH_FULL").map(|v| v == "1").unwrap_or(false) {
        None
    } else {
        Some(40)
    }
}

// Shared synthetic-prompt shapes for the pipeline benches, so hotpath's
// cache-hit probe and throughput's traffic mix agree on what a "prefetched
// answer" looks like (each bench target compiles its own copy of this
// module; allow the ones it doesn't call).

/// Distinct exact-hit prompts (the WhatsApp prefetch-button path).
#[allow(dead_code)]
pub const EXACT_PROMPTS: usize = 64;
/// Distinct SmartCache topics.
#[allow(dead_code)]
pub const TOPICS: usize = 16;
/// Distinct memoized fixed-model prompts.
#[allow(dead_code)]
pub const MEMO_PROMPTS: usize = 16;

#[allow(dead_code)]
pub fn exact_prompt(n: usize) -> String {
    format!("prefetched answer number {}", n % EXACT_PROMPTS)
}

#[allow(dead_code)]
pub fn memo_prompt(n: usize) -> String {
    format!("one fixed dispatch question number {}", n % MEMO_PROMPTS)
}

#[allow(dead_code)]
pub fn topic_prompt(n: usize) -> String {
    format!("tell me about topic number {}", n % TOPICS)
}
