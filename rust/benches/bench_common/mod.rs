//! Shared bench harness setup.

use std::sync::Arc;

use llmbridge::coordinator::{Bridge, BridgeConfig};
use llmbridge::models::pricing::Generation;
use llmbridge::runtime::EngineHandle;

pub fn engine() -> EngineHandle {
    // Deterministic backend on the default build; PJRT over the AOT
    // artifacts under `--features pjrt` (then run `make artifacts` first).
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    EngineHandle::spawn_from_dir(dir).expect("bring up serving backend")
}

pub fn bridge(generation: Generation) -> Arc<Bridge> {
    Arc::new(
        Bridge::from_engine(
            engine(),
            BridgeConfig {
                generation,
                ..Default::default()
            },
        )
        .unwrap(),
    )
}

/// Query budget for replay benches: small by default so `cargo bench`
/// finishes quickly; the `figures` binary regenerates the full-dataset
/// numbers.
pub fn query_limit() -> Option<usize> {
    if std::env::var("LLMBRIDGE_BENCH_FULL").map(|v| v == "1").unwrap_or(false) {
        None
    } else {
        Some(40)
    }
}
