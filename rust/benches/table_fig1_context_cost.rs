//! Bench for Fig 1 (context growth): times the last-k replay end-to-end and
//! prints the paper's series (input tokens + quality percentiles per k).
//!
//! `LLMBRIDGE_BENCH_FULL=1` runs the full 50-query conversation.

mod bench_common;

use llmbridge::experiments as exp;
use llmbridge::models::pricing::Generation;
use llmbridge::util::bench::bench;

fn main() {
    let bridge = bench_common::bridge(Generation::New);
    let limit = bench_common::query_limit().map(|l| l.min(15));

    let mut rows = None;
    let r = bench("fig1/replay_last_k_sweep", 0, 1, || {
        rows = Some(exp::fig1(&bridge, exp::DEFAULT_SEED, limit).unwrap());
    });
    let rows = rows.unwrap();
    println!("\nFig 1a — input tokens vs k (limit={limit:?}):");
    let base = rows[0].input_tokens.max(1);
    for row in &rows {
        println!(
            "  k={:<3} input_tokens={:>7}  x{:>5.1}  cost=${:.4}",
            row.k,
            row.input_tokens,
            row.input_tokens as f64 / base as f64,
            row.cost_usd
        );
    }
    println!("\nFig 1b — quality vs k (reference k=50):");
    for row in &rows {
        let ps = exp::percentiles(row.quality_scores.clone(), &[0.05, 0.2, 0.5]);
        println!(
            "  k={:<3} mean={:.2} p05={:.2} p20={:.2} p50={:.2}",
            row.k,
            exp::mean(&row.quality_scores),
            ps[0].1,
            ps[1].1,
            ps[2].1
        );
    }
    println!(
        "\n[fig1 sweep wall time: {:?} for 5 strategies x {} queries]",
        r.mean,
        rows[0].quality_scores.len()
    );
}
