//! Bench for Fig 4 (model-selection quality, old + new generations):
//! replays the verification cascade vs random routing vs M1-only and prints
//! the paper's CDF rows + escalation fractions.

mod bench_common;

use llmbridge::experiments as exp;
use llmbridge::models::pricing::Generation;
use llmbridge::util::bench::bench;

fn main() {
    let limit = bench_common::query_limit();
    for generation in [Generation::Old, Generation::New] {
        let bridge = bench_common::bridge(generation);
        let mut out = None;
        bench(
            &format!("fig4/replay_{generation:?}"),
            0,
            1,
            || {
                out = Some(
                    exp::fig45(&bridge, exp::DEFAULT_SEED, generation, limit).unwrap(),
                );
            },
        );
        let out = out.unwrap();
        println!(
            "\nFig 4{} ({generation:?} models) — escalation {:.0}% (paper: {}):",
            if generation == Generation::Old { "a" } else { "b" },
            out.escalation_fraction * 100.0,
            if generation == Generation::Old { ">60%" } else { "~25%" }
        );
        for (label, scores) in &out.quality {
            let ps = exp::percentiles(scores.clone(), &[0.05, 0.2, 0.5]);
            println!(
                "  {label:<24} mean={:.2} p05={:.2} p20={:.2} p50={:.2}",
                exp::mean(scores),
                ps[0].1,
                ps[1].1,
                ps[2].1
            );
        }
    }
}
