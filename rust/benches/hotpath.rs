//! Hot-path micro-benchmarks — the L3 profile the perf pass iterates on
//! (EXPERIMENTS.md §Perf): tokenizer, embedding, vecdb scan (flat vs IVF,
//! 20k and 100k rows), JSON, per-execute PJRT latency per variant, batched
//! embeds, and end-to-end dispatch. Writes the results as JSON to the path
//! in `LLMBRIDGE_BENCH_JSON` (see scripts/bench.sh).

mod bench_common;

use llmbridge::api::{CachePolicy, Request, ServiceType};
use llmbridge::cache::{CacheObject, CachedType, SemanticCache};
use llmbridge::models::pricing::{Generation, ModelId};
use llmbridge::persist::wal::{WalOp, WalWriter};
use llmbridge::runtime::tokenizer;
use llmbridge::util::bench::{bench, black_box, BenchReport};
use llmbridge::util::json::Json;
use llmbridge::util::rng::Rng;
use llmbridge::vecdb::flat::FlatIndex;
use llmbridge::vecdb::ivf::IvfIndex;
use llmbridge::vecdb::{Metric, VectorIndex};

fn main() {
    let mut report = BenchReport::new();
    let text = "tell me about vaccination and why people in my community talk about it so much";

    report.record(&bench("tokenizer/window", 100, 5_000, || {
        black_box(tokenizer::window(text, 128));
    }));
    report.record(&bench("tokenizer/count_tokens", 100, 5_000, || {
        black_box(tokenizer::count_tokens(text));
    }));

    // --- vecdb: flat vs IVF at cache-sized corpora -----------------------
    let mut rng = Rng::new(3);
    let mut flat = FlatIndex::new(64, Metric::Cosine);
    let mut ivf = IvfIndex::new(64, Metric::Cosine, 32, 4);
    for i in 0..20_000u64 {
        let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        flat.insert(i, &v).unwrap();
        ivf.insert(i, &v).unwrap();
    }
    ivf.train(7, 4).unwrap();
    let q: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    report.record(&bench("vecdb/flat_top4_20k", 10, 300, || {
        black_box(flat.search(&q, 4, 0.0));
    }));
    report.record(&bench("vecdb/ivf_top4_20k_nprobe4", 10, 300, || {
        black_box(ivf.search(&q, 4, 0.0));
    }));
    // 100k rows: the blocked normalized scan's headroom case.
    let mut flat100 = FlatIndex::new(64, Metric::Cosine);
    for i in 0..100_000u64 {
        let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        flat100.insert(i, &v).unwrap();
    }
    report.record(&bench("vecdb/flat_top4_100k", 5, 100, || {
        black_box(flat100.search(&q, 4, 0.0));
    }));

    // --- JSON substrate ---------------------------------------------------
    let body = r#"{"user":"u1","conversation":"c1","prompt":"tell me about dates and mangoes",
        "service_type":{"name":"model_selector","threshold":8},"update_context":true}"#;
    report.record(&bench("json/parse_request", 100, 5_000, || {
        black_box(Json::parse(body).unwrap());
    }));

    // --- persist: WAL append throughput + cold restore --------------------
    // Engine-free: WAL records carry their vectors, and the bulk restore
    // path replays them without re-embedding.
    let pdir = std::env::temp_dir().join("llmbridge_bench_persist");
    let _ = std::fs::remove_dir_all(&pdir);
    std::fs::create_dir_all(&pdir).unwrap();
    let wal = WalWriter::create(&pdir.join("bench.wal")).unwrap();
    let vec64: Vec<f32> = (0..64).map(|i| (i as f32) * 0.013 + 0.1).collect();
    let mut next = 0u64;
    // The put_interaction shape: one object + prompt/response keys with
    // their 64-dim embeddings, one checksummed record.
    report.record(&bench("persist/wal_append_interaction", 10, 2_000, || {
        next += 3;
        black_box(
            wal.append(&WalOp::PutObject {
                object: CacheObject {
                    id: next,
                    text: "a cached answer about vaccination campaigns".into(),
                    origin: "why do people discuss vaccination".into(),
                    is_document: false,
                },
                keys: vec![
                    (next + 1, CachedType::Prompt, vec64.clone()),
                    (next + 2, CachedType::Response, vec64.clone()),
                ],
            })
            .unwrap(),
        );
    }));
    // Cold restore: 20k entries (10k objects x 2 typed keys) through the
    // validated bulk-load path (vecdb LBV2 + cache.jsonl).
    let big = SemanticCache::new(64);
    for i in 0..10_000u64 {
        let base = i * 3 + 1;
        let jitter = |k: u64| {
            let mut v = vec64.clone();
            v[(k % 64) as usize] += (k as f32) * 1e-4;
            v
        };
        big.apply_logged_put(
            CacheObject {
                id: base,
                text: format!("cold restore object {i}"),
                origin: format!("origin {i}"),
                is_document: false,
            },
            &[
                (base + 1, CachedType::Prompt, jitter(base + 1)),
                (base + 2, CachedType::Response, jitter(base + 2)),
            ],
        )
        .unwrap();
    }
    big.snapshot_into(&pdir).unwrap();
    report.record(&bench("persist/cold_restore_20k", 1, 10, || {
        let back = SemanticCache::restore_from_dir(&pdir, 64).unwrap();
        black_box(back.len_keys());
    }));

    // --- PJRT engine: per-execute latency by variant ----------------------
    let engine = bench_common::engine();
    let (tokens, live) = tokenizer::window(text, engine.seq_len());
    for variant in ["nano", "mini", "large"] {
        let t = tokens.clone();
        report.record(&bench(&format!("engine/lm_step_{variant}"), 3, 40, || {
            black_box(engine.lm_logits(variant, t.clone(), live).unwrap());
        }));
    }
    report.record(&bench("engine/embed_text", 3, 100, || {
        black_box(engine.embed_text(text).unwrap());
    }));
    // 8 distinct texts in one RPC round-trip (the multi-key PUT shape).
    let batch_texts: Vec<String> = (0..8)
        .map(|i| format!("{text} angle number {i}"))
        .collect();
    let batch_refs: Vec<&str> = batch_texts.iter().map(|s| s.as_str()).collect();
    report.record(&bench("engine/embed_batch8", 3, 100, || {
        black_box(engine.embed_batch(&batch_refs).unwrap());
    }));

    // --- end-to-end dispatch (cache hit path = pure L3 overhead) ----------
    let bridge = bench_common::bridge(Generation::New);
    bridge.cache().put_exact("hotpath probe", "cached answer");
    report.record(&bench("pipeline/exact_cache_hit", 10, 500, || {
        let req = Request::new("hp", "c", "hotpath probe").service_type(ServiceType::Cost);
        black_box(bridge.handle(req).unwrap());
    }));
    // Full request (memoized generation: measures proxy overhead + memo).
    let req0 = Request::new("hp", "c2", "one fixed question for dispatch timing")
        .service_type(ServiceType::Fixed {
            model: ModelId::Gpt4oMini,
            cache: CachePolicy::Skip,
            context_k: 0,
        });
    bridge.handle(req0.clone()).unwrap();
    report.record(&bench("pipeline/full_request_memoized", 5, 200, || {
        black_box(bridge.handle(req0.clone()).unwrap());
    }));

    report.write_env("LLMBRIDGE_BENCH_JSON");
}
