//! Hot-path micro-benchmarks — the L3 profile the perf pass iterates on
//! (EXPERIMENTS.md §Perf): tokenizer, embedding, vecdb scan (flat vs IVF
//! vs the adaptive tier at 20k/100k/1M rows, incl. migration/retrain
//! cost, recall@4, the quantized i8 tier, and LBV4 mmap cold boot), JSON,
//! per-execute PJRT latency per variant, batched embeds, and end-to-end
//! dispatch. Writes the results as JSON to the path
//! in `LLMBRIDGE_BENCH_JSON` (see scripts/bench.sh). Under
//! `LLMBRIDGE_BENCH_SMOKE=1` corpora shrink and every bench runs a single
//! iteration — the CI smoke job's populated-JSON proof, not a perf claim.

mod bench_common;

use llmbridge::api::{CachePolicy, Request, ServiceType};
use llmbridge::cache::{CacheObject, CachedType, SemanticCache};
use llmbridge::models::pricing::{Generation, ModelId};
use llmbridge::persist::wal::{WalOp, WalWriter};
use llmbridge::runtime::tokenizer;
use llmbridge::util::bench::{bench, black_box, fast_mode, smoke_mode, BenchReport};
use llmbridge::util::corpus as synth;
use llmbridge::util::json::Json;
use llmbridge::util::rng::Rng;
use llmbridge::vecdb::adaptive::{AdaptiveConfig, AdaptiveIndex};
use llmbridge::vecdb::flat::FlatIndex;
use llmbridge::vecdb::ivf::IvfIndex;
use llmbridge::vecdb::{Metric, VectorIndex};

fn main() {
    let mut report = BenchReport::new();
    let smoke = smoke_mode();
    report.push(
        "bench_mode",
        Json::str(if smoke {
            "smoke"
        } else if fast_mode() {
            "fast"
        } else {
            "full"
        }),
    );
    let text = "tell me about vaccination and why people in my community talk about it so much";

    report.record(&bench("tokenizer/window", 100, 5_000, || {
        black_box(tokenizer::window(text, 128));
    }));
    report.record(&bench("tokenizer/count_tokens", 100, 5_000, || {
        black_box(tokenizer::count_tokens(text));
    }));

    // --- vecdb: flat vs IVF at cache-sized corpora -----------------------
    let n20 = if smoke { 2_000 } else { 20_000 };
    let n100 = if smoke { 10_000 } else { 100_000 };
    let mut rng = Rng::new(3);
    let mut flat = FlatIndex::new(64, Metric::Cosine);
    let mut ivf = IvfIndex::new(64, Metric::Cosine, 32, 4);
    for i in 0..n20 as u64 {
        let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        flat.insert(i, &v).unwrap();
        ivf.insert(i, &v).unwrap();
    }
    ivf.train(7, 4).unwrap();
    let q: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    report.record(&bench("vecdb/flat_top4_20k", 10, 300, || {
        black_box(flat.search(&q, 4, 0.0));
    }));
    report.record(&bench("vecdb/ivf_top4_20k_nprobe4", 10, 300, || {
        black_box(ivf.search(&q, 4, 0.0));
    }));
    // 100k rows: the blocked normalized scan's headroom case.
    let mut flat100 = FlatIndex::new(64, Metric::Cosine);
    for i in 0..n100 as u64 {
        let v: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        flat100.insert(i, &v).unwrap();
    }
    report.record(&bench("vecdb/flat_top4_100k", 5, 100, || {
        black_box(flat100.search(&q, 4, 0.0));
    }));

    // --- vecdb: adaptive tier at deployment scale -------------------------
    // Clustered corpus (cached prompts cluster by topic — the regime the
    // ANN tier is built for); queries are perturbed corpus points, so
    // recall@4 against the exact flat scan is meaningful.
    let corpus: Vec<Vec<f32>> = synth::clustered_pairs(3, n100, 64, 256, 8.0, 0.5)
        .into_iter()
        .map(|(_, v)| v)
        .collect();
    let mut flat_c = FlatIndex::new(64, Metric::Cosine);
    let mut adaptive = AdaptiveIndex::new(64, Metric::Cosine, AdaptiveConfig::default());
    for (i, v) in corpus.iter().enumerate() {
        flat_c.insert(i as u64, v).unwrap();
        adaptive.insert(i as u64, v).unwrap();
    }
    // One-shot flat→IVF migration (plan + k-means + install) — the cost a
    // janitor tick pays off the read path when the corpus crosses the
    // threshold.
    let t0 = std::time::Instant::now();
    let plan = adaptive.rebuild_plan().expect("corpus is past the migration threshold");
    let trained = plan.train();
    let train_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(adaptive.install(trained), "single-threaded: same instance");
    report.push(
        "vecdb/adaptive_migrate_100k",
        Json::obj(vec![
            ("rows", Json::num(n100 as f64)),
            ("train_ms", Json::num(train_ms)),
        ]),
    );
    // Retrain micro-bench at 20k (plan export + k-means, never installed,
    // so each iteration trains from the same flat tier).
    let mut a20 = AdaptiveIndex::new(
        64,
        Metric::Cosine,
        AdaptiveConfig {
            migrate_threshold: 1000,
            ..AdaptiveConfig::default()
        },
    );
    for (i, v) in corpus.iter().take(n20).enumerate() {
        a20.insert(i as u64, v).unwrap();
    }
    report.record(&bench("vecdb/adaptive_retrain_20k", 0, 3, || {
        let plan = a20.rebuild_plan().expect("low threshold keeps rebuild armed");
        black_box(plan.train());
    }));
    // The acceptance pair: exact flat scan vs adaptive (IVF) top-4 GET on
    // the same clustered 100k corpus, plus recall@4 of the latter.
    let qc: Vec<f32> = corpus[n100 / 2].iter().map(|x| x + 0.01).collect();
    let flat_res = bench("vecdb/flat_top4_100k_clustered", 5, 100, || {
        black_box(flat_c.search(&qc, 4, 0.0));
    });
    let adaptive_res = bench("vecdb/adaptive_top4_100k", 10, 300, || {
        black_box(adaptive.search(&qc, 4, 0.0));
    });
    let speedup =
        flat_res.mean.as_secs_f64() / adaptive_res.mean.as_secs_f64().max(1e-12);
    report.record(&flat_res);
    report.record(&adaptive_res);
    let nq = if smoke { 20 } else { 100 };
    let mut found = 0usize;
    let mut total = 0usize;
    for _ in 0..nq {
        let base = rng.choice(&corpus).clone();
        let probe: Vec<f32> = base
            .iter()
            .map(|x| x + rng.normal() as f32 * 0.1)
            .collect();
        let truth = flat_c.search(&probe, 4, 0.0);
        let got = adaptive.search(&probe, 4, 0.0);
        total += truth.len();
        found += truth
            .iter()
            .filter(|t| got.iter().any(|g| g.id == t.id))
            .count();
    }
    report.push(
        "vecdb/adaptive_100k_quality",
        Json::obj(vec![
            ("rows", Json::num(n100 as f64)),
            ("queries", Json::num(nq as f64)),
            ("recall_at4", Json::num(found as f64 / total.max(1) as f64)),
            ("speedup_vs_flat", Json::num(speedup)),
        ]),
    );

    // --- vecdb: quantized i8 tier ----------------------------------------
    // The same 100k clustered corpus forced onto the IVF-i8 tier
    // (quantize_threshold: 1): coarse i8-dot scan + f32 rescore vs the
    // f32 IVF tier above, plus the vector-region bytes/row cut — the two
    // numbers the quantized tier trades against each other.
    let mut quant = AdaptiveIndex::new(
        64,
        Metric::Cosine,
        AdaptiveConfig {
            migrate_threshold: 1000,
            quantize_threshold: 1,
            ..AdaptiveConfig::default()
        },
    );
    for (i, v) in corpus.iter().enumerate() {
        quant.insert(i as u64, v).unwrap();
    }
    let plan = quant.rebuild_plan().expect("past the migration threshold");
    let trained = plan.train();
    assert!(quant.install(trained), "single-threaded: same instance");
    assert_eq!(quant.stats().tier, "ivf_i8", "quantize_threshold 1 forces the i8 tier");
    let quant_res = bench("vecdb/quantized_vs_f32_top4", 10, 300, || {
        black_box(quant.search(&qc, 4, 0.0));
    });
    let quant_speed = adaptive_res.mean.as_secs_f64() / quant_res.mean.as_secs_f64().max(1e-12);
    report.record(&quant_res);
    let (fs, qs) = (adaptive.stats(), quant.stats());
    report.push(
        "vecdb/bytes_per_row",
        Json::obj(vec![
            ("rows", Json::num(fs.rows as f64)),
            (
                "f32_bytes_per_row",
                Json::num(fs.vector_bytes as f64 / fs.rows.max(1) as f64),
            ),
            (
                "i8_bytes_per_row",
                Json::num(qs.vector_bytes as f64 / qs.rows.max(1) as f64),
            ),
            (
                "cut",
                Json::num(fs.vector_bytes as f64 / qs.vector_bytes.max(1) as f64),
            ),
            ("speed_vs_f32_ivf", Json::num(quant_speed)),
        ]),
    );

    // --- vecdb: adaptive tier at 1M rows ----------------------------------
    // The million-row regime the i8 tier exists for. Smoke/fast runs shrink
    // the corpus so CI stays quick; the full run is the headline number.
    let n1m = if smoke {
        50_000
    } else if fast_mode() {
        200_000
    } else {
        1_000_000
    };
    // Row-major flat buffer: one allocation for the staging corpus.
    let big_rows = synth::clustered_rows(11, n1m, 64, 512, 8.0, 0.5);
    let mid = (n1m / 2) * 64;
    let q1m: Vec<f32> = big_rows[mid..mid + 64].iter().map(|x| x + 0.01).collect();
    let mut a1m = AdaptiveIndex::new(
        64,
        Metric::Cosine,
        AdaptiveConfig {
            migrate_threshold: 1000,
            quantize_threshold: 1,
            ..AdaptiveConfig::default()
        },
    );
    for (i, v) in big_rows.chunks(64).enumerate() {
        a1m.insert(i as u64, v).unwrap();
    }
    // Free the f32 staging corpus before timing: past this point only the
    // index's own storage is live (flat f32 rows until install, i8 after).
    drop(big_rows);
    let t0 = std::time::Instant::now();
    let plan = a1m.rebuild_plan().expect("past the migration threshold");
    let trained = plan.train();
    let train_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert!(a1m.install(trained), "single-threaded: same instance");
    assert_eq!(a1m.stats().tier, "ivf_i8");
    report.push(
        "vecdb/adaptive_migrate_1m",
        Json::obj(vec![
            ("rows", Json::num(n1m as f64)),
            ("train_ms", Json::num(train_ms)),
        ]),
    );
    report.record(&bench("vecdb/adaptive_top4_1m", 5, 200, || {
        black_box(a1m.search(&q1m, 4, 0.0));
    }));

    // --- JSON substrate ---------------------------------------------------
    let body = r#"{"user":"u1","conversation":"c1","prompt":"tell me about dates and mangoes",
        "service_type":{"name":"model_selector","threshold":8},"update_context":true}"#;
    report.record(&bench("json/parse_request", 100, 5_000, || {
        black_box(Json::parse(body).unwrap());
    }));

    // --- persist: WAL append throughput + cold restore --------------------
    // Engine-free: WAL records carry their vectors, and the bulk restore
    // path replays them without re-embedding.
    let pdir = std::env::temp_dir().join("llmbridge_bench_persist");
    let _ = std::fs::remove_dir_all(&pdir);
    std::fs::create_dir_all(&pdir).unwrap();
    let wal = WalWriter::create(&pdir.join("bench.wal")).unwrap();
    let vec64: Vec<f32> = (0..64).map(|i| (i as f32) * 0.013 + 0.1).collect();
    let mut next = 0u64;
    // The put_interaction shape: one object + prompt/response keys with
    // their 64-dim embeddings, one checksummed record.
    report.record(&bench("persist/wal_append_interaction", 10, 2_000, || {
        next += 3;
        black_box(
            wal.append(&WalOp::PutObject {
                object: CacheObject {
                    id: next,
                    text: "a cached answer about vaccination campaigns".into(),
                    origin: "why do people discuss vaccination".into(),
                    is_document: false,
                },
                keys: vec![
                    (next + 1, CachedType::Prompt, vec64.clone()),
                    (next + 2, CachedType::Response, vec64.clone()),
                ],
            })
            .unwrap(),
        );
    }));
    // Cold restore: 20k entries (10k objects x 2 typed keys) through the
    // validated bulk-load path (vecdb LBV2 + cache.jsonl).
    let big = SemanticCache::new(64);
    for i in 0..10_000u64 {
        let base = i * 3 + 1;
        let jitter = |k: u64| {
            let mut v = vec64.clone();
            v[(k % 64) as usize] += (k as f32) * 1e-4;
            v
        };
        big.apply_logged_put(
            CacheObject {
                id: base,
                text: format!("cold restore object {i}"),
                origin: format!("origin {i}"),
                is_document: false,
            },
            &[
                (base + 1, CachedType::Prompt, jitter(base + 1)),
                (base + 2, CachedType::Response, jitter(base + 2)),
            ],
        )
        .unwrap();
    }
    big.snapshot_into(&pdir).unwrap();
    report.record(&bench("persist/cold_restore_20k", 1, 10, || {
        let back = SemanticCache::restore_from_dir(&pdir, 64).unwrap();
        black_box(back.len_keys());
    }));
    // LBV4 mmap cold boot: save the quantized 100k index, then time load +
    // one top-4 query. The unix load path maps the i8 code region instead
    // of reading it, so this measures restore-to-first-answer (metadata
    // parse + one probe's worth of page faults), not snapshot size.
    let vpath = pdir.join("bench_quant.lbv4");
    quant.save(&vpath).unwrap();
    report.record(&bench("persist/restore_to_first_query", 1, 20, || {
        let back = AdaptiveIndex::load(
            &vpath,
            AdaptiveConfig {
                migrate_threshold: 1000,
                quantize_threshold: 1,
                ..AdaptiveConfig::default()
            },
        )
        .unwrap();
        black_box(back.search(&qc, 4, 0.0));
    }));

    // --- engine: per-execute latency by variant (serving backend) ---------
    let engine = bench_common::engine();
    let (tokens, live) = tokenizer::window(text, engine.seq_len());
    for variant in ["nano", "mini", "large"] {
        let t = tokens.clone();
        report.record(&bench(&format!("engine/lm_step_{variant}"), 3, 40, || {
            black_box(engine.lm_logits(variant, t.clone(), live).unwrap());
        }));
    }
    report.record(&bench("engine/embed_text", 3, 100, || {
        black_box(engine.embed_text(text).unwrap());
    }));
    // 8 distinct texts in one RPC round-trip (the multi-key PUT shape).
    let batch_texts: Vec<String> = (0..8)
        .map(|i| format!("{text} angle number {i}"))
        .collect();
    let batch_refs: Vec<&str> = batch_texts.iter().map(|s| s.as_str()).collect();
    report.record(&bench("engine/embed_batch8", 3, 100, || {
        black_box(engine.embed_batch(&batch_refs).unwrap());
    }));

    // --- end-to-end dispatch (cache hit path = pure L3 overhead) ----------
    let bridge = bench_common::bridge(Generation::New);
    // Same prompt shape as throughput.rs's exact-hit mix (bench_common).
    let probe = bench_common::exact_prompt(0);
    bridge.cache().put_exact(&probe, "cached answer");
    report.record(&bench("pipeline/exact_cache_hit", 10, 500, || {
        let req = Request::new("hp", "c", &probe).service_type(ServiceType::Cost);
        black_box(bridge.handle(req).unwrap());
    }));
    // Full request (memoized generation: measures proxy overhead + memo).
    let req0 = Request::new("hp", "c2", "one fixed question for dispatch timing")
        .service_type(ServiceType::Fixed {
            model: ModelId::Gpt4oMini,
            cache: CachePolicy::Skip,
            context_k: 0,
        });
    bridge.handle(req0.clone()).unwrap();
    report.record(&bench("pipeline/full_request_memoized", 5, 200, || {
        black_box(bridge.handle(req0.clone()).unwrap());
    }));

    report.write_env("LLMBRIDGE_BENCH_JSON");
}
