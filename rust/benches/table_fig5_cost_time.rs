//! Bench for Fig 5 (model-selection cost & time, old generation): prints
//! normalized total cost and total LLM time per strategy.

mod bench_common;

use llmbridge::experiments as exp;
use llmbridge::models::pricing::Generation;
use llmbridge::util::bench::bench;

fn main() {
    let bridge = bench_common::bridge(Generation::Old);
    let limit = bench_common::query_limit();
    let mut out = None;
    bench("fig5/replay_old_generation", 0, 1, || {
        out = Some(exp::fig45(&bridge, exp::DEFAULT_SEED, Generation::Old, limit).unwrap());
    });
    let out = out.unwrap();

    println!("\nFig 5a — cost normalized to M1-only (paper: verification ~40% under M2-only):");
    for (label, c) in &out.cost {
        println!("  {label:<24} x{c:.2}");
    }
    let verify = out
        .cost
        .iter()
        .find(|(l, _)| l.starts_with("verification"))
        .unwrap()
        .1;
    let m2 = out.cost.last().unwrap().1;
    println!(
        "  -> verification vs M2-only: {:.0}% cheaper",
        (1.0 - verify / m2) * 100.0
    );

    println!("\nFig 5b — LLM time normalized to M1-only (paper: verification ~5x M1):");
    for (label, t) in &out.time {
        println!("  {label:<24} x{t:.2}");
    }
}
