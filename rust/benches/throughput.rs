//! Multi-threaded closed-loop throughput bench: N worker threads × M
//! requests against one `Bridge`, mixing exact-hit, semantic-hit
//! (SmartCache), and memoized-generation traffic — the scaling probe for
//! the sharded cache + batched engine hot path. Reports requests/sec and
//! p50/p99 latency at 1, 4, and 8 threads, and writes JSON to the path in
//! `LLMBRIDGE_BENCH_JSON` so the BENCH trajectory can track scaling
//! across PRs (ROADMAP.md §Perf trajectory).
//!
//! Traffic mix per 8 requests: 5 exact hits (the WhatsApp prefetch-button
//! path), 2 memoized fixed-model generations (proxy overhead + memo), and
//! 1 SmartCache request (embed + cache-LLM relevance + grounded reply).

mod bench_common;

use std::sync::Arc;
use std::time::Instant;

use llmbridge::api::{CachePolicy, Request, ServiceType};
use llmbridge::coordinator::Bridge;
use llmbridge::models::pricing::{Generation, ModelId};
use llmbridge::util::bench::{fast_mode, BenchReport};
use llmbridge::util::json::Json;

use bench_common::{exact_prompt, memo_prompt, topic_prompt, EXACT_PROMPTS, MEMO_PROMPTS, TOPICS};

fn request_for(thread: usize, i: usize) -> Request {
    let user = format!("worker{thread}");
    // Stride by a thread-dependent odd step so threads don't hit the same
    // entry in lockstep (that would hide shard contention).
    let n = thread * 31 + i;
    match i % 8 {
        5 | 6 => Request::new(&user, "memo", &memo_prompt(n))
            .service_type(ServiceType::Fixed {
                model: ModelId::Gpt4oMini,
                cache: CachePolicy::Skip,
                context_k: 0,
            })
            .no_context_update(),
        7 => Request::new(&user, "smart", &topic_prompt(n))
            .service_type(ServiceType::SmartCache {
                model: ModelId::Claude3Haiku,
            })
            .no_context_update(),
        _ => Request::new(&user, "exact", &exact_prompt(n))
            .service_type(ServiceType::Cost)
            .no_context_update(),
    }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the closed loop; returns (requests/sec, p50 us, p99 us).
fn run_closed_loop(bridge: &Arc<Bridge>, threads: usize, per_thread: usize) -> (f64, u64, u64) {
    let start = Instant::now();
    let mut all: Vec<u64> = Vec::with_capacity(threads * per_thread);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let bridge = Arc::clone(bridge);
                s.spawn(move || {
                    let mut samples = Vec::with_capacity(per_thread);
                    for i in 0..per_thread {
                        let req = request_for(t, i);
                        let t0 = Instant::now();
                        bridge.handle(req).expect("throughput request failed");
                        samples.push(t0.elapsed().as_micros() as u64);
                    }
                    samples
                })
            })
            .collect();
        for h in handles {
            all.extend(h.join().unwrap());
        }
    });
    let wall = start.elapsed().as_secs_f64();
    all.sort_unstable();
    (
        all.len() as f64 / wall.max(1e-9),
        percentile(&all, 0.50),
        percentile(&all, 0.99),
    )
}

fn main() {
    let bridge = bench_common::bridge(Generation::New);

    // ---- seed the cache and memo tables (untimed) -----------------------
    for n in 0..EXACT_PROMPTS {
        bridge
            .cache()
            .put_exact(&exact_prompt(n), &format!("cached reply {n}"));
    }
    for n in 0..TOPICS {
        bridge
            .cache()
            .put_interaction(
                bridge.generator(),
                &topic_prompt(n),
                &format!("topic number {n} matters because of reasons {n}"),
            )
            .unwrap();
    }
    // Warm the generation memo for both delayed paths so the timed loop
    // measures proxy overhead, not first-touch PJRT decoding. Every memo
    // prompt and topic is touched once, from every worker user id (the
    // SmartCache classify call is seeded per query, not per user, but the
    // warmup is cheap and keeps the timed loop fully memoized).
    for t in 0..8 {
        let user = format!("worker{t}");
        for n in 0..MEMO_PROMPTS {
            let req = Request::new(&user, "memo", &memo_prompt(n))
                .service_type(ServiceType::Fixed {
                    model: ModelId::Gpt4oMini,
                    cache: CachePolicy::Skip,
                    context_k: 0,
                })
                .no_context_update();
            bridge.handle(req).unwrap();
        }
        for n in 0..TOPICS {
            let req = Request::new(&user, "smart", &topic_prompt(n))
                .service_type(ServiceType::SmartCache {
                    model: ModelId::Claude3Haiku,
                })
                .no_context_update();
            bridge.handle(req).unwrap();
        }
    }

    let per_thread = if fast_mode() { 40 } else { 400 };
    let mut report = BenchReport::new();
    let mut rps_by_threads: Vec<(usize, f64)> = Vec::new();
    for &threads in &[1usize, 4, 8] {
        let (rps, p50, p99) = run_closed_loop(&bridge, threads, per_thread);
        println!(
            "throughput {threads:>2} threads  {:>9.0} req/s  p50 {p50:>7} us  p99 {p99:>7} us  ({} reqs)",
            rps,
            threads * per_thread
        );
        rps_by_threads.push((threads, rps));
        report.push(
            &format!("throughput/{threads}_threads"),
            Json::obj(vec![
                ("threads", Json::num(threads as f64)),
                ("requests", Json::num((threads * per_thread) as f64)),
                ("rps", Json::num(rps)),
                ("p50_us", Json::num(p50 as f64)),
                ("p99_us", Json::num(p99 as f64)),
            ]),
        );
    }
    if let (Some((_, r1)), Some((_, r8))) = (
        rps_by_threads.iter().find(|(t, _)| *t == 1),
        rps_by_threads.iter().find(|(t, _)| *t == 8),
    ) {
        let scaling = r8 / r1.max(1e-9);
        println!("throughput scaling 8t/1t: {scaling:.2}x");
        report.push(
            "throughput/scaling_8v1",
            Json::obj(vec![("ratio", Json::num(scaling))]),
        );
    }
    report.write_env("LLMBRIDGE_BENCH_JSON");
}
