//! Multi-threaded closed-loop throughput bench: N worker threads × M
//! requests against one `Bridge`, mixing exact-hit, semantic-hit
//! (SmartCache), and memoized-generation traffic — the scaling probe for
//! the sharded cache + batched engine hot path. Reports requests/sec and
//! p50/p99 latency at 1, 4, and 8 threads, and writes JSON to the path in
//! `LLMBRIDGE_BENCH_JSON` so the BENCH trajectory can track scaling
//! across PRs (ROADMAP.md §Perf trajectory).
//!
//! Traffic mix per 8 requests: 5 exact hits (the WhatsApp prefetch-button
//! path), 2 memoized fixed-model generations (proxy overhead + memo), and
//! 1 SmartCache request (embed + cache-LLM relevance + grounded reply).
//!
//! A second, **open-loop** section drives a real evented `Server` over
//! loopback with keep-alive connections on a fixed arrival schedule —
//! latency measured from the *scheduled* arrival (no coordinated
//! omission) — at ~0.6× and ~1.5× of the server's own closed-loop HTTP
//! capacity. The overload leg shows admission-control shedding (429s)
//! keeping tail latency bounded instead of queues melting; both legs
//! land in BENCH_throughput.json (`throughput/open_loop_*`).
//!
//! A final **breaker-open** leg trips one model's circuit breaker and
//! measures the fast-fail path: typed 503s served before cache or
//! engine are touched (`throughput/breaker_open`).

mod bench_common;

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use llmbridge::api::{CachePolicy, Request, ServiceType};
use llmbridge::coordinator::Bridge;
use llmbridge::models::pricing::{Generation, ModelId};
use llmbridge::server::{Server, ServerBackend, ServerConfig};
use llmbridge::util::bench::{fast_mode, BenchReport};
use llmbridge::util::json::Json;

use bench_common::{exact_prompt, memo_prompt, topic_prompt, EXACT_PROMPTS, MEMO_PROMPTS, TOPICS};

fn request_for(thread: usize, i: usize) -> Request {
    let user = format!("worker{thread}");
    // Stride by a thread-dependent odd step so threads don't hit the same
    // entry in lockstep (that would hide shard contention).
    let n = thread * 31 + i;
    match i % 8 {
        5 | 6 => Request::new(&user, "memo", &memo_prompt(n))
            .service_type(ServiceType::Fixed {
                model: ModelId::Gpt4oMini,
                cache: CachePolicy::Skip,
                context_k: 0,
            })
            .no_context_update(),
        7 => Request::new(&user, "smart", &topic_prompt(n))
            .service_type(ServiceType::SmartCache {
                model: ModelId::Claude3Haiku,
            })
            .no_context_update(),
        _ => Request::new(&user, "exact", &exact_prompt(n))
            .service_type(ServiceType::Cost)
            .no_context_update(),
    }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Run the closed loop; returns (requests/sec, p50 us, p99 us).
fn run_closed_loop(bridge: &Arc<Bridge>, threads: usize, per_thread: usize) -> (f64, u64, u64) {
    let start = Instant::now();
    let mut all: Vec<u64> = Vec::with_capacity(threads * per_thread);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let bridge = Arc::clone(bridge);
                s.spawn(move || {
                    let mut samples = Vec::with_capacity(per_thread);
                    for i in 0..per_thread {
                        let req = request_for(t, i);
                        let t0 = Instant::now();
                        bridge.handle(req).expect("throughput request failed");
                        samples.push(t0.elapsed().as_micros() as u64);
                    }
                    samples
                })
            })
            .collect();
        for h in handles {
            all.extend(h.join().unwrap());
        }
    });
    let wall = start.elapsed().as_secs_f64();
    all.sort_unstable();
    (
        all.len() as f64 / wall.max(1e-9),
        percentile(&all, 0.50),
        percentile(&all, 0.99),
    )
}

/// Minimal keep-alive HTTP client framing responses by Content-Length.
struct OlClient {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl OlClient {
    fn connect(addr: std::net::SocketAddr) -> OlClient {
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        OlClient {
            stream,
            buf: Vec::new(),
        }
    }

    /// POST /v1/request on the persistent connection; returns the status.
    fn roundtrip(&mut self, user: &str, prompt: &str) -> u16 {
        let body = format!(
            r#"{{"user":"{user}","conversation":"ol","prompt":"{prompt}",
                "service_type":{{"name":"cost"}}}}"#
        );
        self.roundtrip_body(&body)
    }

    /// [`Self::roundtrip`] with a caller-built JSON body.
    fn roundtrip_body(&mut self, body: &str) -> u16 {
        let msg = format!(
            "POST /v1/request HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.stream.write_all(msg.as_bytes()).unwrap();
        let head_end = loop {
            if let Some(p) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break p + 4;
            }
            let mut tmp = [0u8; 4096];
            let n = self.stream.read(&mut tmp).expect("server closed mid-bench");
            assert!(n > 0, "server closed mid-bench");
            self.buf.extend_from_slice(&tmp[..n]);
        };
        let head = std::str::from_utf8(&self.buf[..head_end]).unwrap();
        let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
        let clen: usize = head
            .lines()
            .find_map(|l| {
                let (k, v) = l.split_once(':')?;
                if k.eq_ignore_ascii_case("content-length") {
                    v.trim().parse().ok()
                } else {
                    None
                }
            })
            .unwrap_or(0);
        while self.buf.len() < head_end + clen {
            let mut tmp = [0u8; 4096];
            let n = self.stream.read(&mut tmp).expect("server closed mid-body");
            assert!(n > 0, "server closed mid-body");
            self.buf.extend_from_slice(&tmp[..n]);
        }
        self.buf.drain(..head_end + clen);
        status
    }
}

struct OpenLoopResult {
    offered_rps: f64,
    served: usize,
    shed: usize,
    p50_us: u64,
    p99_us: u64,
}

impl OpenLoopResult {
    fn shed_rate(&self) -> f64 {
        self.shed as f64 / (self.served + self.shed).max(1) as f64
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("offered_rps", Json::num(self.offered_rps)),
            ("served", Json::num(self.served as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("shed_rate", Json::num(self.shed_rate())),
            ("p50_us", Json::num(self.p50_us as f64)),
            ("p99_us", Json::num(self.p99_us as f64)),
        ])
    }
}

/// Closed-loop HTTP calibration: `conns` keep-alive connections hammer
/// back-to-back; returns the server's req/s ceiling for this machine.
fn http_closed_loop_rps(addr: std::net::SocketAddr, conns: usize, per_conn: usize) -> f64 {
    let start = Instant::now();
    std::thread::scope(|s| {
        for t in 0..conns {
            s.spawn(move || {
                let mut c = OlClient::connect(addr);
                let user = format!("ol-u{t}");
                for i in 0..per_conn {
                    c.roundtrip(&user, &exact_prompt((t * 31 + i) % EXACT_PROMPTS));
                }
            });
        }
    });
    (conns * per_conn) as f64 / start.elapsed().as_secs_f64().max(1e-9)
}

/// Open loop: requests arrive on a fixed global schedule (`offered_rps`),
/// round-robin across `conns` keep-alive connections, one user per
/// connection (per-user serialization stays out of the way). Latency is
/// measured from the **scheduled** arrival time, so a server that falls
/// behind pays its queueing delay in the percentiles.
fn run_open_loop(
    addr: std::net::SocketAddr,
    conns: usize,
    offered_rps: f64,
    duration: Duration,
) -> OpenLoopResult {
    let total = (offered_rps * duration.as_secs_f64()).ceil() as usize;
    let interval = Duration::from_secs_f64(1.0 / offered_rps.max(1.0));
    let t0 = Instant::now() + Duration::from_millis(50);
    let mut served = 0usize;
    let mut shed = 0usize;
    let mut all: Vec<u64> = Vec::with_capacity(total);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns)
            .map(|t| {
                s.spawn(move || {
                    let mut c = OlClient::connect(addr);
                    let user = format!("ol-u{t}");
                    let mut samples: Vec<(u64, bool)> = Vec::new();
                    let mut k = t;
                    while k < total {
                        let sched = t0 + interval.mul_f64(k as f64);
                        if let Some(wait) = sched.checked_duration_since(Instant::now()) {
                            std::thread::sleep(wait);
                        }
                        let status =
                            c.roundtrip(&user, &exact_prompt((t * 31 + k) % EXACT_PROMPTS));
                        let lat = Instant::now().duration_since(sched).as_micros() as u64;
                        samples.push((lat, status == 200));
                        k += conns;
                    }
                    samples
                })
            })
            .collect();
        for h in handles {
            for (lat, ok) in h.join().unwrap() {
                if ok {
                    served += 1;
                    all.push(lat);
                } else {
                    shed += 1;
                }
            }
        }
    });
    all.sort_unstable();
    OpenLoopResult {
        offered_rps,
        served,
        shed,
        p50_us: percentile(&all, 0.50),
        p99_us: percentile(&all, 0.99),
    }
}

fn main() {
    let bridge = bench_common::bridge(Generation::New);

    // ---- seed the cache and memo tables (untimed) -----------------------
    for n in 0..EXACT_PROMPTS {
        bridge
            .cache()
            .put_exact(&exact_prompt(n), &format!("cached reply {n}"));
    }
    for n in 0..TOPICS {
        bridge
            .cache()
            .put_interaction(
                bridge.generator(),
                &topic_prompt(n),
                &format!("topic number {n} matters because of reasons {n}"),
            )
            .unwrap();
    }
    // Warm the generation memo for both delayed paths so the timed loop
    // measures proxy overhead, not first-touch PJRT decoding. Every memo
    // prompt and topic is touched once, from every worker user id (the
    // SmartCache classify call is seeded per query, not per user, but the
    // warmup is cheap and keeps the timed loop fully memoized).
    for t in 0..8 {
        let user = format!("worker{t}");
        for n in 0..MEMO_PROMPTS {
            let req = Request::new(&user, "memo", &memo_prompt(n))
                .service_type(ServiceType::Fixed {
                    model: ModelId::Gpt4oMini,
                    cache: CachePolicy::Skip,
                    context_k: 0,
                })
                .no_context_update();
            bridge.handle(req).unwrap();
        }
        for n in 0..TOPICS {
            let req = Request::new(&user, "smart", &topic_prompt(n))
                .service_type(ServiceType::SmartCache {
                    model: ModelId::Claude3Haiku,
                })
                .no_context_update();
            bridge.handle(req).unwrap();
        }
    }

    let per_thread = if fast_mode() { 40 } else { 400 };
    let mut report = BenchReport::new();
    let mut rps_by_threads: Vec<(usize, f64)> = Vec::new();
    for &threads in &[1usize, 4, 8] {
        let (rps, p50, p99) = run_closed_loop(&bridge, threads, per_thread);
        println!(
            "throughput {threads:>2} threads  {:>9.0} req/s  p50 {p50:>7} us  p99 {p99:>7} us  ({} reqs)",
            rps,
            threads * per_thread
        );
        rps_by_threads.push((threads, rps));
        report.push(
            &format!("throughput/{threads}_threads"),
            Json::obj(vec![
                ("threads", Json::num(threads as f64)),
                ("requests", Json::num((threads * per_thread) as f64)),
                ("rps", Json::num(rps)),
                ("p50_us", Json::num(p50 as f64)),
                ("p99_us", Json::num(p99 as f64)),
            ]),
        );
    }
    if let (Some((_, r1)), Some((_, r8))) = (
        rps_by_threads.iter().find(|(t, _)| *t == 1),
        rps_by_threads.iter().find(|(t, _)| *t == 8),
    ) {
        let scaling = r8 / r1.max(1e-9);
        println!("throughput scaling 8t/1t: {scaling:.2}x");
        report.push(
            "throughput/scaling_8v1",
            Json::obj(vec![("ratio", Json::num(scaling))]),
        );
    }

    // ---- open-loop section: a real server over loopback -----------------
    // Calibrate the server's closed-loop HTTP ceiling, then offer fixed
    // arrival rates at 0.6× (healthy) and 1.5× (overload). The shed
    // watermark sits below the connection count so the overload leg
    // exercises admission control rather than just client-side queueing.
    let backend = if cfg!(target_os = "linux") {
        ServerBackend::Evented
    } else {
        ServerBackend::Threaded
    };
    let server = Server::start_with(
        Arc::clone(&bridge),
        "127.0.0.1:0",
        ServerConfig {
            workers: 4,
            shed_watermark: 8,
            backend,
            ..ServerConfig::default()
        },
    )
    .expect("start server for open-loop bench");
    let conns = 32;
    let (cal_per_conn, leg_secs) = if fast_mode() { (20, 1.0) } else { (100, 3.0) };
    let cap = http_closed_loop_rps(server.addr, conns, cal_per_conn);
    println!(
        "open-loop calibration: {cap:>9.0} req/s closed-loop over HTTP ({conns} keep-alive conns)"
    );
    let legs = [("0.6x", 0.6), ("1.5x", 1.5)].map(|(label, frac)| {
        let r = run_open_loop(
            server.addr,
            conns,
            cap * frac,
            Duration::from_secs_f64(leg_secs),
        );
        println!(
            "open_loop {label}  offered {:>8.0} req/s  served {:>6}  shed {:>5} ({:>4.1}%)  p50 {:>7} us  p99 {:>7} us",
            r.offered_rps,
            r.served,
            r.shed,
            r.shed_rate() * 100.0,
            r.p50_us,
            r.p99_us
        );
        report.push(&format!("throughput/open_loop_{label}"), r.to_json());
        r
    });
    report.push(
        "throughput/open_loop_p99",
        Json::obj(vec![
            ("calibrated_rps", Json::num(cap)),
            ("underload_p99_us", Json::num(legs[0].p99_us as f64)),
            ("overload_p99_us", Json::num(legs[1].p99_us as f64)),
            ("overload_shed_rate", Json::num(legs[1].shed_rate())),
        ]),
    );

    // ---- breaker-open fast-fail leg -------------------------------------
    // Trip one model's circuit breaker, then hammer that model over the
    // same keep-alive path. Every request sheds with the typed 503 before
    // touching cache or engine; the interesting numbers are how cheap
    // saying "no" is (p99 far below a served request) and the fast-fail
    // req/s ceiling a sick upstream leaves the proxy with.
    let sick = ModelId::Gpt4oMini.as_str();
    for _ in 0..bridge.breaker().config().threshold {
        bridge.breaker().record_failure(sick);
    }
    let shots = if fast_mode() { 200 } else { 1000 };
    let mut c = OlClient::connect(server.addr);
    let mut lat: Vec<u64> = Vec::with_capacity(shots);
    let mut shed_503 = 0usize;
    let t0 = Instant::now();
    for i in 0..shots {
        let body = format!(
            r#"{{"user":"brk","conversation":"brk","prompt":"breaker probe {i}",
                "service_type":{{"name":"fixed","model":"{sick}","cache":"skip"}}}}"#
        );
        let s0 = Instant::now();
        if c.roundtrip_body(&body) == 503 {
            shed_503 += 1;
        }
        lat.push(s0.elapsed().as_micros() as u64);
    }
    let fail_rps = shots as f64 / t0.elapsed().as_secs_f64().max(1e-9);
    lat.sort_unstable();
    let (bp50, bp99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
    println!(
        "breaker_open  {fail_rps:>9.0} req/s fast-fail  503s {shed_503}/{shots}  p50 {bp50:>7} us  p99 {bp99:>7} us"
    );
    report.push(
        "throughput/breaker_open",
        Json::obj(vec![
            ("requests", Json::num(shots as f64)),
            ("shed_503", Json::num(shed_503 as f64)),
            ("fast_fail_rps", Json::num(fail_rps)),
            ("p50_us", Json::num(bp50 as f64)),
            ("p99_us", Json::num(bp99 as f64)),
        ]),
    );
    server.stop();

    report.write_env("LLMBRIDGE_BENCH_JSON");
}
