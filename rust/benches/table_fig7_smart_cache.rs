//! Bench for Fig 7 (SmartCache): populates the cache from the synthetic
//! encyclopedia via delegated PUT and compares grounded small-model answers
//! against direct GPT-4o-class / Phi-3-class answers on factual queries.

mod bench_common;

use llmbridge::experiments as exp;
use llmbridge::models::pricing::Generation;
use llmbridge::util::bench::bench;

fn main() {
    let bridge = bench_common::bridge(Generation::New);
    let limit = bench_common::query_limit();
    let mut out = None;
    bench("fig7/replay_smart_cache", 0, 1, || {
        out = Some(exp::fig7(&bridge, exp::DEFAULT_SEED, limit).unwrap());
    });
    let out = out.unwrap();

    println!(
        "\nFig 7 — {} factual queries, cache used on {}:",
        out.n_factual, out.n_cache_used
    );
    println!("\nFig 7a — quality vs sonar-huge-online reference:");
    for (label, scores) in &out.quality {
        let min = scores.iter().cloned().fold(f64::INFINITY, f64::min);
        println!(
            "  {label:<28} mean={:.2} min={:.2}",
            exp::mean(scores),
            min
        );
    }
    println!("\nFig 7b — subset where smart_cache used the cache (paper: min 4 vs 1):");
    for (label, scores) in &out.cache_used_quality {
        let min = scores.iter().cloned().fold(f64::INFINITY, f64::min);
        println!(
            "  {label:<28} mean={:.2} min={:.2}",
            exp::mean(scores),
            min
        );
    }
}
