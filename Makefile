# Build-time targets. The rust crate's default build needs none of this —
# `make artifacts` AOT-compiles the JAX/Pallas model pool (L2/L1) into
# artifacts/ for the `--features pjrt` serving path (see README.md
# §PJRT backend). Requires python3 + jax.

.PHONY: artifacts clean-artifacts

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

clean-artifacts:
	rm -rf artifacts
