#!/usr/bin/env bash
# Perf-trajectory runner: build release, run the hotpath, throughput, and
# scenario benches, and write BENCH_hotpath.json / BENCH_throughput.json /
# BENCH_scenarios.json at the repo root so successive PRs have a
# comparable baseline.
#
# The hotpath bench includes the persist micro-benches
# (persist/wal_append_interaction, persist/cold_restore_20k, and
# persist/restore_to_first_query — the LBV4 mmap cold-boot probe) and the
# adaptive vector-index benches (vecdb/adaptive_top4_100k, migration +
# retrain cost, recall@4, plus the quantized-tier pair
# vecdb/quantized_vs_f32_top4 / vecdb/bytes_per_row and the million-row
# vecdb/adaptive_top4_1m, which smoke/fast modes shrink to 50k/200k rows)
# so WAL throughput, cold-restore time, and the ANN tier all ride the
# same trajectory file.
#
# The throughput bench ends with an open-loop probe against a real
# evented server over loopback: it calibrates the server's closed-loop
# HTTP ceiling, then offers fixed arrival rates at 0.6x and 1.5x of it,
# measuring p50/p99 from the *scheduled* arrival (no coordinated
# omission) plus the admission-shed rate. Results land in
# BENCH_throughput.json under throughput/open_loop_0.6x,
# throughput/open_loop_1.5x, and the summary throughput/open_loop_p99.
#
# The scenarios bench generalizes that probe to the full trace-driven
# scenario matrix (underload, diurnal overload + shedding, breaker trip,
# cache cold/warm, two-node sync, live reconfiguration with the
# old-or-new-snapshot invariant); one scenarios/<name> entry per scenario
# lands in BENCH_scenarios.json.
#
# Usage: scripts/bench.sh [--fast|--smoke]
#   --fast    shrink iteration counts (LLMBRIDGE_BENCH_FAST=1).
#   --smoke   CI smoke: reduced corpus sizes + a single iteration per
#             bench (LLMBRIDGE_BENCH_SMOKE=1). Proves the harness runs
#             end-to-end and emits populated JSON; not a perf claim.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

case "${1:-}" in
  --fast)
    export LLMBRIDGE_BENCH_FAST=1
    ;;
  --smoke)
    export LLMBRIDGE_BENCH_SMOKE=1
    export LLMBRIDGE_BENCH_FAST=1
    ;;
  "")
    ;;
  *)
    echo "bench.sh: unknown flag '$1' (expected --fast or --smoke)" >&2
    exit 2
    ;;
esac

# Fail loudly when the toolchain is absent: a silent exit here would leave
# stale BENCH_*.json at the repo root masquerading as fresh numbers.
if ! command -v cargo >/dev/null 2>&1; then
  echo "bench.sh: cargo not found on PATH — install the pinned toolchain" \
       "(see rust-toolchain.toml) before running benches; BENCH_*.json" \
       "left untouched" >&2
  exit 1
fi

# The cargo workspace may sit at the repo root or under rust/.
if [[ -f "$ROOT/Cargo.toml" ]]; then
  WORKSPACE="$ROOT"
elif [[ -f "$ROOT/rust/Cargo.toml" ]]; then
  WORKSPACE="$ROOT/rust"
else
  echo "bench.sh: no Cargo.toml at $ROOT or $ROOT/rust — set up the workspace first" >&2
  exit 1
fi

cd "$WORKSPACE"
cargo build --release

LLMBRIDGE_BENCH_JSON="$ROOT/BENCH_hotpath.json" \
  cargo bench --bench hotpath

LLMBRIDGE_BENCH_JSON="$ROOT/BENCH_throughput.json" \
  cargo bench --bench throughput

LLMBRIDGE_BENCH_JSON="$ROOT/BENCH_scenarios.json" \
  cargo bench --bench scenarios

echo "wrote $ROOT/BENCH_hotpath.json, $ROOT/BENCH_throughput.json and $ROOT/BENCH_scenarios.json"
