#!/usr/bin/env bash
# Perf-trajectory runner: build release, run the hotpath and throughput
# benches, and write BENCH_hotpath.json / BENCH_throughput.json at the
# repo root so successive PRs have a comparable baseline.
#
# The hotpath bench includes the persist micro-benches
# (persist/wal_append_interaction, persist/cold_restore_20k) so WAL
# append throughput and cold-restore time ride the same trajectory file.
#
# Usage: scripts/bench.sh [--fast]
#   --fast   shrink iteration counts (LLMBRIDGE_BENCH_FAST=1) for CI.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

if [[ "${1:-}" == "--fast" ]]; then
  export LLMBRIDGE_BENCH_FAST=1
fi

# The cargo workspace may sit at the repo root or under rust/.
if [[ -f "$ROOT/Cargo.toml" ]]; then
  WORKSPACE="$ROOT"
elif [[ -f "$ROOT/rust/Cargo.toml" ]]; then
  WORKSPACE="$ROOT/rust"
else
  echo "bench.sh: no Cargo.toml at $ROOT or $ROOT/rust — set up the workspace first" >&2
  exit 1
fi

cd "$WORKSPACE"
cargo build --release

LLMBRIDGE_BENCH_JSON="$ROOT/BENCH_hotpath.json" \
  cargo bench --bench hotpath

LLMBRIDGE_BENCH_JSON="$ROOT/BENCH_throughput.json" \
  cargo bench --bench throughput

echo "wrote $ROOT/BENCH_hotpath.json and $ROOT/BENCH_throughput.json"
