#!/usr/bin/env bash
# The tier-1 CI gate, runnable locally: exactly the steps
# .github/workflows/ci.yml runs, in the same order, so local runs and CI
# cannot drift. Green here == green in the `gate` job.
#
# Usage: scripts/ci.sh
#
# Steps: cargo build --release, cargo test --workspace -q (a superset of
# the ROADMAP tier-1 `cargo test -q`: it also runs the vendored xla-stub
# member's tests), the same test suite again under
# LLMBRIDGE_FORCE_SCALAR=1 (pins the vecdb dot kernels to the scalar
# path, so the SIMD parity tests prove bit-exactness against the fallback
# the runtime would actually use on a machine without AVX2/NEON), a
# release-mode server stress pass (the evented-loop suite: 1k+ concurrent
# keep-alive connections, connection churn, induced overload/shedding —
# plus the ops-resilience suite: panic isolation, breaker trips,
# rate-limit hot-reload, admin surface — plus the two-node replication
# convergence harness — debug-mode timing hides races the optimized loop
# would hit), a release-mode smoke of the open-loop scenario matrix on
# both server backends (LLMBRIDGE_BENCH_SMOKE=1 --test scenarios: the
# reduced-corpus traffic matrix plus the live-reconfiguration snapshot
# invariant), then
# cargo fmt --check, cargo clippy --workspace -D warnings, rustdoc with
# -D warnings (the docs gate — broken intra-doc links and malformed docs
# fail the build, so module docs can't rot), a pure-shell markdown link
# check over README.md/ROADMAP.md/docs/ (relative link targets must
# exist — the same can't-rot contract for the prose docs), and a
# `--features pjrt` type-check of the engine path against the stub.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

if ! command -v cargo >/dev/null 2>&1; then
  echo "ci.sh: cargo not found on PATH — install rustup and the pinned" \
       "toolchain (rust-toolchain.toml pins it; 'rustup show' in the repo" \
       "fetches it automatically)" >&2
  exit 1
fi

# The cargo workspace may sit at the repo root or under rust/.
if [[ -f "$ROOT/Cargo.toml" ]]; then
  WORKSPACE="$ROOT"
elif [[ -f "$ROOT/rust/Cargo.toml" ]]; then
  WORKSPACE="$ROOT/rust"
else
  echo "ci.sh: no Cargo.toml at $ROOT or $ROOT/rust — set up the workspace first" >&2
  exit 1
fi
cd "$WORKSPACE"

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q (workspace: crate + vendored stub)"
cargo test --workspace -q

echo "==> force-scalar: LLMBRIDGE_FORCE_SCALAR=1 cargo test -q (kernel fallback gate)"
LLMBRIDGE_FORCE_SCALAR=1 cargo test --workspace -q

echo "==> server stress: cargo test --release --test server_evented --test server_http --test server_ops --test replication"
cargo test --release --test server_evented --test server_http --test server_ops --test replication -q

echo "==> scenario matrix smoke: LLMBRIDGE_BENCH_SMOKE=1 cargo test --release --test scenarios"
# Release mode: the open-loop driver holds scheduled arrival times against
# a live server on both backends; debug-mode timing would distort the
# shapes the assertions (shed reasons, cutover invariant) depend on.
LLMBRIDGE_BENCH_SMOKE=1 cargo test --release --test scenarios -q

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

echo "==> cargo doc --no-deps (RUSTDOCFLAGS=-D warnings)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "==> markdown link check (README.md, ROADMAP.md, docs/)"
# Pure shell + grep/sed: every relative inline-link target must exist on
# disk, resolved against the file that contains it. External URLs and
# in-page #fragments are skipped; a target's own #anchor is stripped
# before the existence check.
link_fail=0
for doc in "$ROOT/README.md" "$ROOT/ROADMAP.md" "$ROOT"/docs/*.md; do
  [[ -f "$doc" ]] || continue
  doc_dir="$(dirname "$doc")"
  while IFS= read -r target; do
    case "$target" in
      http://*|https://*|mailto:*|"#"*) continue ;;
    esac
    rel="${target%%#*}"
    [[ -n "$rel" ]] || continue
    if [[ ! -e "$doc_dir/$rel" ]]; then
      echo "ci.sh: broken link in ${doc#"$ROOT"/}: ($target)" >&2
      link_fail=1
    fi
  done < <(grep -o '\[[^]]*\]([^)]*)' "$doc" | sed 's/^\[[^]]*\](\([^)]*\))$/\1/')
done
if [[ "$link_fail" -ne 0 ]]; then
  echo "ci.sh: markdown link check failed" >&2
  exit 1
fi

echo "==> cargo check --features pjrt (engine path vs the vendored xla stub)"
cargo check --features pjrt --all-targets

echo "ci.sh: all gates green"
