#!/usr/bin/env bash
# The tier-1 CI gate, runnable locally: exactly the steps
# .github/workflows/ci.yml runs, in the same order, so local runs and CI
# cannot drift. Green here == green in the `gate` job.
#
# Usage: scripts/ci.sh
#
# Steps: cargo build --release && cargo test -q  (the ROADMAP tier-1
# verify), then cargo fmt --check and cargo clippy -D warnings.
set -euo pipefail

ROOT="$(cd "$(dirname "$0")/.." && pwd)"

if ! command -v cargo >/dev/null 2>&1; then
  echo "ci.sh: cargo not found on PATH — install rustup and the pinned" \
       "toolchain (rust-toolchain.toml pins it; 'rustup show' in the repo" \
       "fetches it automatically)" >&2
  exit 1
fi

# The cargo workspace may sit at the repo root or under rust/.
if [[ -f "$ROOT/Cargo.toml" ]]; then
  WORKSPACE="$ROOT"
elif [[ -f "$ROOT/rust/Cargo.toml" ]]; then
  WORKSPACE="$ROOT/rust"
else
  echo "ci.sh: no Cargo.toml at $ROOT or $ROOT/rust — set up the workspace first" >&2
  exit 1
fi
cd "$WORKSPACE"

echo "==> tier-1: cargo build --release"
cargo build --release

echo "==> tier-1: cargo test -q"
cargo test -q

echo "==> cargo fmt --check"
cargo fmt --all -- --check

echo "==> cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

echo "ci.sh: all gates green"
